//! Hedged backup requests (§3.1, after Dean's "tail at scale"):
//! "The Router uses hedged backup requests to mitigate latency spikes
//! from transient server issues or inter-request or -model
//! interference."
//!
//! Strategy: send to a primary replica; if no response arrives within
//! `hedge_delay` (ideally ≈ p95 of healthy latency), send the same
//! request to a backup replica; first response wins. Costs a bounded
//! fraction of duplicate work, removes most of the tail. Experiment T6
//! (`benches/bench_hedging.rs`) reproduces the claim.

use super::client::ClientPool;
use super::proto::{Request, Response};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

pub struct HedgedClient {
    pool: Arc<ClientPool>,
    /// Wait this long before firing the backup request.
    pub hedge_delay: Duration,
    hedges_fired: AtomicU64,
    calls: AtomicU64,
}

impl HedgedClient {
    pub fn new(pool: Arc<ClientPool>, hedge_delay: Duration) -> Self {
        HedgedClient {
            pool,
            hedge_delay,
            hedges_fired: AtomicU64::new(0),
            calls: AtomicU64::new(0),
        }
    }

    /// Call `replicas[0]`, hedging to `replicas[1..]` after the delay.
    /// First successful response wins; losers are discarded (their
    /// connections are dropped, not pooled, to avoid response skew).
    pub fn call(&self, replicas: &[String], req: &Request) -> Result<Response> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let first = replicas
            .first()
            .ok_or_else(|| anyhow!("no replicas to call"))?;

        let (tx, rx) = mpsc::channel::<Result<Response>>();
        self.spawn_attempt(first.clone(), req.clone(), tx.clone());

        // Wait for the primary up to the hedge delay.
        match rx.recv_timeout(self.hedge_delay) {
            Ok(Ok(resp)) => return Ok(resp),
            Ok(Err(primary_err)) => {
                // Primary failed fast: go straight to a backup if any.
                match replicas.get(1) {
                    Some(backup) => {
                        self.hedges_fired.fetch_add(1, Ordering::Relaxed);
                        self.spawn_attempt(backup.clone(), req.clone(), tx);
                        return rx
                            .recv_timeout(Duration::from_secs(30))
                            .map_err(|_| anyhow!("backup timed out"))?;
                    }
                    None => return Err(primary_err),
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(e) => return Err(anyhow!("hedge channel: {e}")),
        }

        // Primary is slow: fire the backup, take whichever lands first.
        if let Some(backup) = replicas.get(1) {
            self.hedges_fired.fetch_add(1, Ordering::Relaxed);
            self.spawn_attempt(backup.clone(), req.clone(), tx);
        }
        let mut last_err = None;
        // Up to two outstanding attempts can report.
        for _ in 0..2 {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(resp)) => return Ok(resp),
                Ok(Err(e)) => last_err = Some(e),
                Err(_) => break,
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("all hedged attempts timed out")))
    }

    fn spawn_attempt(&self, addr: String, req: Request, tx: mpsc::Sender<Result<Response>>) {
        let pool = Arc::clone(&self.pool);
        std::thread::Builder::new()
            .name("hedge-attempt".to_string())
            .spawn(move || {
                let result = pool
                    .get(&addr)
                    .and_then(|mut c| {
                        let r = c.call(&req);
                        if r.is_ok() {
                            pool.put(c);
                        }
                        r
                    })
                    .and_then(Response::into_result);
                let _ = tx.send(result);
            })
            .expect("spawn hedge attempt");
    }

    /// Fraction of calls that fired a backup request.
    pub fn hedge_rate(&self) -> f64 {
        let calls = self.calls.load(Ordering::Relaxed);
        if calls == 0 {
            0.0
        } else {
            self.hedges_fired.load(Ordering::Relaxed) as f64 / calls as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::RpcServer;
    use std::sync::atomic::AtomicBool;

    /// Server whose handler can be made artificially slow.
    fn server(slow: Arc<AtomicBool>, delay: Duration) -> Arc<RpcServer> {
        RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| {
                if slow.load(Ordering::SeqCst) {
                    std::thread::sleep(delay);
                }
                match req {
                    Request::Ping => Response::Pong,
                    _ => Response::Error {
                        kind: crate::base::error::ErrorKind::Internal,
                        message: "no".into(),
                    },
                }
            }),
        )
        .unwrap()
    }

    #[test]
    fn fast_primary_no_hedge() {
        let s = server(Arc::new(AtomicBool::new(false)), Duration::ZERO);
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(100));
        let replicas = vec![s.addr().to_string()];
        for _ in 0..10 {
            assert_eq!(h.call(&replicas, &Request::Ping).unwrap(), Response::Pong);
        }
        assert_eq!(h.hedge_rate(), 0.0);
    }

    #[test]
    fn slow_primary_hedges_to_backup() {
        let slow = Arc::new(AtomicBool::new(true));
        let primary = server(Arc::clone(&slow), Duration::from_millis(500));
        let backup = server(Arc::new(AtomicBool::new(false)), Duration::ZERO);
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(20));
        let replicas = vec![primary.addr().to_string(), backup.addr().to_string()];

        let t0 = std::time::Instant::now();
        assert_eq!(h.call(&replicas, &Request::Ping).unwrap(), Response::Pong);
        // Must return via the backup (~20ms + rtt), far below 500ms.
        assert!(t0.elapsed() < Duration::from_millis(300), "{:?}", t0.elapsed());
        assert!(h.hedge_rate() > 0.0);
    }

    #[test]
    fn dead_primary_fails_over() {
        let backup = server(Arc::new(AtomicBool::new(false)), Duration::ZERO);
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(50));
        let replicas = vec!["127.0.0.1:1".to_string(), backup.addr().to_string()];
        assert_eq!(h.call(&replicas, &Request::Ping).unwrap(), Response::Pong);
    }

    #[test]
    fn no_replicas_errors() {
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(1));
        assert!(h.call(&[], &Request::Ping).is_err());
    }

    #[test]
    fn single_dead_replica_errors() {
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(10));
        assert!(h.call(&["127.0.0.1:1".to_string()], &Request::Ping).is_err());
    }
}
