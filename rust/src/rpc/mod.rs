//! RPC substrate: framed TCP transport, hand-rolled codecs, threaded
//! server, pooled client, and hedged backup requests (§3.1).
//!
//! The paper's deployments sit behind Google RPC infrastructure, which
//! §4 explicitly factors out of the serving-overhead claim; this module
//! is the swappable stand-in. Wire format: 4-byte little-endian length
//! prefix + binary message ([`proto`]).

pub mod client;
pub mod frame;
pub mod hedged;
pub mod proto;
pub mod server;
