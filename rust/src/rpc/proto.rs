//! Wire messages and their binary codecs.
//!
//! Inference messages mirror §2.2's APIs redesigned around
//! signature-addressed inference: every data-plane request carries a
//! [`ModelSpec`] (name + version **or version label**), `Predict`
//! carries a named-input tensor map against a named signature and
//! returns named outputs, `GetModelMetadata` reports per-version
//! [`SignatureDef`]s, and `MultiInference` fans several
//! classify/regress heads over one example batch. Admin messages carry
//! the TFS² control plane (SetAspired from the Synchronizer,
//! SetVersionLabel for canary/stable rollouts, ModelStatus back).
//! Codec style matches `inference::example`: u8 tags + u32 le length
//! prefixes, no self-description.
//!
//! Hot-path codec properties: request tensors decode **straight into
//! pooled tensor storage** (wire bytes → the buffer the serving layer
//! will read, no intermediate `Vec`; f32 and i32 alike), responses
//! encode from tensor views without materializing owned copies, and
//! [`Request::encode_framed_into`]/[`Response::encode_framed_into`]
//! reserve the 4-byte frame header inside the scratch buffer so
//! connection loops reuse one allocation **and** reply with a single
//! `write` syscall ([`super::frame::write_framed`]).

use crate::base::error::ErrorKind;
use crate::base::tensor::{Tensor, TensorI32};
use crate::inference::example::Example;
use crate::inference::multi::{HeadResult, InferenceMethod, InferenceTask};
use crate::inference::ModelSpec;
use crate::runtime::artifacts::{SignatureDef, TensorInfo};
use crate::runtime::pjrt::OutTensor;
use crate::util::pool::BufferPool;
use anyhow::{anyhow, bail, Result};

/// A request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Named input tensors against a named signature (`""` = default
    /// serving signature); returns named outputs.
    Predict { spec: ModelSpec, signature: String, inputs: Vec<(String, Tensor)> },
    Classify { spec: ModelSpec, signature: String, examples: Vec<Example> },
    Regress { spec: ModelSpec, signature: String, examples: Vec<Example> },
    /// N classify/regress heads over one shared example batch.
    MultiInference { spec: ModelSpec, tasks: Vec<InferenceTask>, examples: Vec<Example> },
    /// Per-version signature defs, labels, and state for a model.
    GetModelMetadata { spec: ModelSpec },
    Lookup { table: String, key: String },
    /// Admin: full aspired-version set for one servable (RPC source).
    SetAspired { model: String, versions: Vec<u64> },
    /// Admin: attach (or move) a version label to a serving version.
    SetVersionLabel { model: String, label: String, version: u64 },
    /// Admin: detach a version label (the inverse of `SetVersionLabel`;
    /// exposed over REST as `DELETE /v1/models/{name}/labels/{label}`).
    DeleteVersionLabel { model: String, label: String },
    /// Admin: which versions of `model` are in which state?
    ModelStatus { model: String },
    /// Admin: server metrics/status dump.
    Status,
    /// Admin: structured metric samples (what the TFS² Synchronizer
    /// scrapes for autoscaling — lane depths, queue delays, sheds).
    Metrics,
    /// Admin: fleet-pushed rollout status for `model` (canary phase,
    /// auto-rollback reason), surfaced in `GET /v1/models`. An empty
    /// `status` clears the entry.
    SetRolloutStatus { model: String, status: String },
    /// Liveness probe / no-op (used by benches to measure RPC floor).
    Ping,
    /// Deadline envelope: the inner request must complete within
    /// `deadline_ms` of the server *receiving* it, or be answered with
    /// `DEADLINE_EXCEEDED` — and crucially, expired work is dropped
    /// before it reaches the device, never after. Nesting envelopes is
    /// a decode error.
    WithDeadline { deadline_ms: u64, inner: Box<Request> },
}

impl Request {
    /// Legacy-shaped Predict: one unnamed tensor, default signature.
    pub fn predict(model: impl Into<String>, version: Option<u64>, input: Tensor) -> Request {
        Request::Predict {
            spec: ModelSpec::named(model, version),
            signature: String::new(),
            inputs: vec![(String::new(), input)],
        }
    }

    /// Legacy-shaped Classify: default signature.
    pub fn classify(
        model: impl Into<String>,
        version: Option<u64>,
        examples: Vec<Example>,
    ) -> Request {
        Request::Classify {
            spec: ModelSpec::named(model, version),
            signature: String::new(),
            examples,
        }
    }

    /// Legacy-shaped Regress: default signature.
    pub fn regress(
        model: impl Into<String>,
        version: Option<u64>,
        examples: Vec<Example>,
    ) -> Request {
        Request::Regress {
            spec: ModelSpec::named(model, version),
            signature: String::new(),
            examples,
        }
    }

    /// Wrap `self` in a deadline envelope. Wrapping an envelope
    /// replaces its deadline instead of nesting (the wire format
    /// forbids nested envelopes).
    pub fn with_deadline_ms(self, deadline_ms: u64) -> Request {
        match self {
            Request::WithDeadline { inner, .. } => {
                Request::WithDeadline { deadline_ms, inner }
            }
            other => Request::WithDeadline { deadline_ms, inner: Box::new(other) },
        }
    }
}

/// Per-version metadata in a `ModelMetadata` response.
#[derive(Debug, Clone, PartialEq)]
pub struct VersionMetadata {
    pub version: u64,
    /// Lifecycle state label ("ready", "loading", …).
    pub state: String,
    /// Version labels currently attached ("canary", "stable", …).
    pub labels: Vec<String>,
    /// Named signatures this version serves (empty for non-HLO
    /// platforms, which have no tensor signatures).
    pub signatures: Vec<(String, SignatureDef)>,
}

/// A response frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Predict { model_version: u64, outputs: Vec<(String, OutTensor)> },
    Classify { model_version: u64, classes: Vec<i32>, log_probs: Vec<Vec<f32>> },
    Regress { model_version: u64, values: Vec<f32> },
    MultiInference { model_version: u64, results: Vec<(String, HeadResult)> },
    ModelMetadata { model: String, versions: Vec<VersionMetadata> },
    Lookup { values: Option<Vec<f32>> },
    Ack,
    ModelStatus { versions: Vec<(u64, String)> },
    Status { text: String },
    /// Structured metric samples, name-sorted: counters and gauges by
    /// name, histograms expanded to `.count`/`.mean`/`.p50`/`.p99`/
    /// `.max` — machine-readable where `Status` is a text dump.
    Metrics { samples: Vec<(String, f64)> },
    Pong,
    /// A typed serving error: `kind` is the structured classification
    /// (what the client should do), `message` the human detail. The
    /// HTTP gateway maps status codes from `kind`, not message text.
    Error { kind: ErrorKind, message: String },
}

// ------------------------------------------------------------ helpers

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_opt_version(out: &mut Vec<u8>, v: Option<u64>) {
    match v {
        Some(v) => {
            out.push(1);
            put_u64(out, v);
        }
        None => out.push(0),
    }
}

fn put_model_spec(out: &mut Vec<u8>, spec: &ModelSpec) {
    put_str(out, &spec.name);
    put_opt_version(out, spec.version);
    match &spec.label {
        Some(l) => {
            out.push(1);
            put_str(out, l);
        }
        None => out.push(0),
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    put_u32(out, t.shape().len() as u32);
    for &d in t.shape() {
        put_u32(out, d as u32);
    }
    put_f32s(out, t.data());
}

fn put_named_tensors(out: &mut Vec<u8>, inputs: &[(String, Tensor)]) {
    put_u32(out, inputs.len() as u32);
    for (name, t) in inputs {
        put_str(out, name);
        put_tensor(out, t);
    }
}

fn put_examples(out: &mut Vec<u8>, examples: &[Example]) {
    put_u32(out, examples.len() as u32);
    for ex in examples {
        let enc = ex.encode();
        put_u32(out, enc.len() as u32);
        out.extend_from_slice(&enc);
    }
}

fn put_tasks(out: &mut Vec<u8>, tasks: &[InferenceTask]) {
    put_u32(out, tasks.len() as u32);
    for task in tasks {
        out.push(match task.method {
            InferenceMethod::Classify => 0,
            InferenceMethod::Regress => 1,
        });
        put_str(out, &task.signature);
    }
}

fn put_tensor_info(out: &mut Vec<u8>, info: &TensorInfo) {
    put_str(out, &info.name);
    put_str(out, &info.dtype);
    put_u32(out, info.shape.len() as u32);
    for &d in &info.shape {
        put_u64(out, d as u64);
    }
}

fn put_signature_def(out: &mut Vec<u8>, def: &SignatureDef) {
    put_str(out, &def.method);
    put_u32(out, def.inputs.len() as u32);
    for i in &def.inputs {
        put_tensor_info(out, i);
    }
    put_u32(out, def.outputs.len() as u32);
    for o in &def.outputs {
        put_tensor_info(out, o);
    }
}

fn put_version_metadata(out: &mut Vec<u8>, vm: &VersionMetadata) {
    put_u64(out, vm.version);
    put_str(out, &vm.state);
    put_u32(out, vm.labels.len() as u32);
    for l in &vm.labels {
        put_str(out, l);
    }
    put_u32(out, vm.signatures.len() as u32);
    for (name, def) in &vm.signatures {
        put_str(out, name);
        put_signature_def(out, def);
    }
}

fn put_head_result(out: &mut Vec<u8>, head: &HeadResult) {
    match head {
        HeadResult::Classify { classes, log_probs } => {
            out.push(0);
            put_u32(out, classes.len() as u32);
            for c in classes {
                out.extend_from_slice(&c.to_le_bytes());
            }
            put_u32(out, log_probs.len() as u32);
            for row in log_probs {
                put_f32s(out, row);
            }
        }
        HeadResult::Regress { values } => {
            out.push(1);
            put_f32s(out, values);
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn u8(&mut self) -> Result<u8> {
        let v = *self.buf.get(self.pos).ok_or_else(|| anyhow!("truncated u8"))?;
        self.pos += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        if end > self.buf.len() {
            bail!("truncated u32");
        }
        let v = u32::from_le_bytes(self.buf[self.pos..end].try_into().unwrap());
        self.pos = end;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64> {
        let end = self.pos + 8;
        if end > self.buf.len() {
            bail!("truncated u64");
        }
        let v = u64::from_le_bytes(self.buf[self.pos..end].try_into().unwrap());
        self.pos = end;
        Ok(v)
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos + n;
        if end > self.buf.len() {
            bail!("truncated bytes({n})");
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > 1 << 20 {
            bail!("implausible string length {n}");
        }
        Ok(std::str::from_utf8(self.bytes(n)?)?.to_string())
    }

    fn opt_version(&mut self) -> Result<Option<u64>> {
        Ok(match self.u8()? {
            0 => None,
            1 => Some(self.u64()?),
            t => bail!("bad option tag {t}"),
        })
    }

    fn model_spec(&mut self) -> Result<ModelSpec> {
        let name = self.str()?;
        let version = self.opt_version()?;
        let label = match self.u8()? {
            0 => None,
            1 => Some(self.str()?),
            t => bail!("bad option tag {t}"),
        };
        Ok(ModelSpec { name, version, label })
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn shape(&mut self) -> Result<Vec<usize>> {
        let rank = self.u32()? as usize;
        if rank > 8 {
            bail!("implausible rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u32()? as usize);
        }
        Ok(shape)
    }

    /// Decode a tensor by writing wire bytes directly into pooled
    /// storage — the buffer handed to the serving layer, zero
    /// intermediate copies.
    fn tensor(&mut self) -> Result<Tensor> {
        let shape = self.shape()?;
        let want = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| anyhow!("tensor shape {shape:?} overflows"))?;
        let n = self.u32()? as usize;
        if n != want {
            bail!("tensor data length {n} != shape {shape:?} product {want}");
        }
        let raw = self.bytes(n * 4)?;
        Ok(Tensor::build_with(shape, &BufferPool::global(), |buf| {
            for (dst, src) in buf.iter_mut().zip(raw.chunks_exact(4)) {
                *dst = f32::from_le_bytes(src.try_into().unwrap());
            }
        }))
    }

    fn named_tensors(&mut self) -> Result<Vec<(String, Tensor)>> {
        let n = self.u32()? as usize;
        if n > 1 << 16 {
            bail!("implausible input count {n}");
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let name = self.str()?;
            out.push((name, self.tensor()?));
        }
        Ok(out)
    }

    fn examples(&mut self) -> Result<Vec<Example>> {
        let n = self.u32()? as usize;
        if n > 1 << 20 {
            bail!("implausible example count {n}");
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let len = self.u32()? as usize;
            out.push(Example::decode(self.bytes(len)?)?);
        }
        Ok(out)
    }

    fn tasks(&mut self) -> Result<Vec<InferenceTask>> {
        let n = self.u32()? as usize;
        if n > 1 << 16 {
            bail!("implausible task count {n}");
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let method = match self.u8()? {
                0 => InferenceMethod::Classify,
                1 => InferenceMethod::Regress,
                t => bail!("unknown inference method {t}"),
            };
            out.push(InferenceTask { signature: self.str()?, method });
        }
        Ok(out)
    }

    fn tensor_info(&mut self) -> Result<TensorInfo> {
        let name = self.str()?;
        let dtype = self.str()?;
        let rank = self.u32()? as usize;
        if rank > 8 {
            bail!("implausible rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u64()? as i64);
        }
        Ok(TensorInfo { name, dtype, shape })
    }

    fn signature_def(&mut self) -> Result<SignatureDef> {
        let method = self.str()?;
        let ni = self.u32()? as usize;
        if ni > 1 << 10 {
            bail!("implausible input count {ni}");
        }
        let mut inputs = Vec::with_capacity(ni);
        for _ in 0..ni {
            inputs.push(self.tensor_info()?);
        }
        let no = self.u32()? as usize;
        if no > 1 << 10 {
            bail!("implausible output count {no}");
        }
        let mut outputs = Vec::with_capacity(no);
        for _ in 0..no {
            outputs.push(self.tensor_info()?);
        }
        Ok(SignatureDef { method, inputs, outputs })
    }

    fn version_metadata(&mut self) -> Result<VersionMetadata> {
        let version = self.u64()?;
        let state = self.str()?;
        let nl = self.u32()? as usize;
        if nl > 1 << 10 {
            bail!("implausible label count {nl}");
        }
        let mut labels = Vec::with_capacity(nl);
        for _ in 0..nl {
            labels.push(self.str()?);
        }
        let ns = self.u32()? as usize;
        if ns > 1 << 10 {
            bail!("implausible signature count {ns}");
        }
        let mut signatures = Vec::with_capacity(ns);
        for _ in 0..ns {
            let name = self.str()?;
            signatures.push((name, self.signature_def()?));
        }
        Ok(VersionMetadata { version, state, labels, signatures })
    }

    fn head_result(&mut self) -> Result<HeadResult> {
        Ok(match self.u8()? {
            0 => {
                let n = self.u32()? as usize;
                let raw = self.bytes(n * 4)?;
                let classes = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let m = self.u32()? as usize;
                if m > 1 << 20 {
                    bail!("implausible row count {m}");
                }
                let mut log_probs = Vec::with_capacity(m);
                for _ in 0..m {
                    log_probs.push(self.f32s()?);
                }
                HeadResult::Classify { classes, log_probs }
            }
            1 => HeadResult::Regress { values: self.f32s()? },
            t => bail!("unknown head result tag {t}"),
        })
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("trailing bytes in message");
        }
        Ok(())
    }
}

// ------------------------------------------------- REST binary payloads
//
// The `application/x-tensorserve` REST content-type
// ([`crate::http::wire::binary`]) reuses this module's tensor framing
// so latency-sensitive clients skip JSON while keeping HTTP routing:
// the HTTP body is exactly a payload below (the model comes from the
// URL path, so no `ModelSpec` is framed), and responses are
// [`Response::encode`] bytes.

/// Encode a predict payload: `signature` + named input tensors.
pub fn encode_predict_payload(out: &mut Vec<u8>, signature: &str, inputs: &[(String, Tensor)]) {
    put_str(out, signature);
    put_named_tensors(out, inputs);
}

/// Decode a predict payload (tensor bytes land straight in pooled
/// storage, exactly like the RPC plane's decode).
pub fn decode_predict_payload(buf: &[u8]) -> Result<(String, Vec<(String, Tensor)>)> {
    let mut r = Reader::new(buf);
    let signature = r.str()?;
    let inputs = r.named_tensors()?;
    r.done()?;
    Ok((signature, inputs))
}

/// Encode a classify/regress payload: `signature` + examples.
pub fn encode_examples_payload(out: &mut Vec<u8>, signature: &str, examples: &[Example]) {
    put_str(out, signature);
    put_examples(out, examples);
}

/// Decode a classify/regress payload.
pub fn decode_examples_payload(buf: &[u8]) -> Result<(String, Vec<Example>)> {
    let mut r = Reader::new(buf);
    let signature = r.str()?;
    let examples = r.examples()?;
    r.done()?;
    Ok((signature, examples))
}

// -------------------------------------------------------------- codecs

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Encode into a caller-owned scratch buffer (cleared first), so
    /// connection loops reuse one allocation across requests.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        self.encode_body(out);
    }

    /// Encode with 4 reserved header bytes at the front, ready for
    /// [`super::frame::write_framed`]'s single-syscall frame write.
    pub fn encode_framed_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&[0u8; super::frame::HEADER]);
        self.encode_body(out);
    }

    fn encode_body(&self, out: &mut Vec<u8>) {
        match self {
            Request::Predict { spec, signature, inputs } => {
                out.push(0);
                put_model_spec(out, spec);
                put_str(out, signature);
                put_named_tensors(out, inputs);
            }
            Request::Classify { spec, signature, examples } => {
                out.push(1);
                put_model_spec(out, spec);
                put_str(out, signature);
                put_examples(out, examples);
            }
            Request::Regress { spec, signature, examples } => {
                out.push(2);
                put_model_spec(out, spec);
                put_str(out, signature);
                put_examples(out, examples);
            }
            Request::Lookup { table, key } => {
                out.push(3);
                put_str(out, table);
                put_str(out, key);
            }
            Request::SetAspired { model, versions } => {
                out.push(4);
                put_str(out, model);
                put_u32(out, versions.len() as u32);
                for v in versions {
                    put_u64(out, *v);
                }
            }
            Request::ModelStatus { model } => {
                out.push(5);
                put_str(out, model);
            }
            Request::Status => out.push(6),
            Request::Ping => out.push(7),
            Request::GetModelMetadata { spec } => {
                out.push(8);
                put_model_spec(out, spec);
            }
            Request::MultiInference { spec, tasks, examples } => {
                out.push(9);
                put_model_spec(out, spec);
                put_tasks(out, tasks);
                put_examples(out, examples);
            }
            Request::SetVersionLabel { model, label, version } => {
                out.push(10);
                put_str(out, model);
                put_str(out, label);
                put_u64(out, *version);
            }
            Request::DeleteVersionLabel { model, label } => {
                out.push(11);
                put_str(out, model);
                put_str(out, label);
            }
            Request::WithDeadline { deadline_ms, inner } => {
                out.push(12);
                put_u64(out, *deadline_ms);
                inner.encode_body(out);
            }
            Request::Metrics => out.push(13),
            Request::SetRolloutStatus { model, status } => {
                out.push(14);
                put_str(out, model);
                put_str(out, status);
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Request> {
        let mut r = Reader::new(buf);
        let req = Self::decode_with(&mut r, true)?;
        r.done()?;
        Ok(req)
    }

    fn decode_with(r: &mut Reader<'_>, allow_envelope: bool) -> Result<Request> {
        let req = match r.u8()? {
            0 => Request::Predict {
                spec: r.model_spec()?,
                signature: r.str()?,
                inputs: r.named_tensors()?,
            },
            1 => Request::Classify {
                spec: r.model_spec()?,
                signature: r.str()?,
                examples: r.examples()?,
            },
            2 => Request::Regress {
                spec: r.model_spec()?,
                signature: r.str()?,
                examples: r.examples()?,
            },
            3 => Request::Lookup { table: r.str()?, key: r.str()? },
            4 => {
                let model = r.str()?;
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("implausible version count {n}");
                }
                let mut versions = Vec::with_capacity(n);
                for _ in 0..n {
                    versions.push(r.u64()?);
                }
                Request::SetAspired { model, versions }
            }
            5 => Request::ModelStatus { model: r.str()? },
            6 => Request::Status,
            7 => Request::Ping,
            8 => Request::GetModelMetadata { spec: r.model_spec()? },
            9 => Request::MultiInference {
                spec: r.model_spec()?,
                tasks: r.tasks()?,
                examples: r.examples()?,
            },
            10 => Request::SetVersionLabel {
                model: r.str()?,
                label: r.str()?,
                version: r.u64()?,
            },
            11 => Request::DeleteVersionLabel { model: r.str()?, label: r.str()? },
            12 => {
                if !allow_envelope {
                    bail!("nested deadline envelope");
                }
                let deadline_ms = r.u64()?;
                Request::WithDeadline {
                    deadline_ms,
                    inner: Box::new(Self::decode_with(r, false)?),
                }
            }
            13 => Request::Metrics,
            14 => Request::SetRolloutStatus { model: r.str()?, status: r.str()? },
            t => bail!("unknown request tag {t}"),
        };
        Ok(req)
    }
}

fn put_out_tensor(out: &mut Vec<u8>, t: &OutTensor) {
    match t {
        OutTensor::F32(t) => {
            out.push(0);
            put_tensor(out, t);
        }
        OutTensor::I32(t) => {
            out.push(1);
            put_u32(out, t.shape().len() as u32);
            for &d in t.shape() {
                put_u32(out, d as u32);
            }
            put_u32(out, t.data().len() as u32);
            for x in t.data() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

fn read_out_tensor(r: &mut Reader<'_>) -> Result<OutTensor> {
    Ok(match r.u8()? {
        0 => OutTensor::F32(r.tensor()?),
        1 => {
            let shape = r.shape()?;
            let want = shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| anyhow!("tensor shape {shape:?} overflows"))?;
            let n = r.u32()? as usize;
            if n != want {
                bail!("tensor data length {n} != shape {shape:?} product {want}");
            }
            let raw = r.bytes(n * 4)?;
            // i32 wire tensors land in pooled storage too.
            OutTensor::I32(TensorI32::build_with(
                shape,
                &BufferPool::global_i32(),
                |buf| {
                    for (dst, src) in buf.iter_mut().zip(raw.chunks_exact(4)) {
                        *dst = i32::from_le_bytes(src.try_into().unwrap());
                    }
                },
            ))
        }
        t => bail!("unknown tensor tag {t}"),
    })
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Encode into a caller-owned scratch buffer (cleared first), so
    /// connection loops reuse one allocation across responses.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        self.encode_body(out);
    }

    /// Encode with 4 reserved header bytes at the front, ready for
    /// [`super::frame::write_framed`]'s single-syscall frame write.
    pub fn encode_framed_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&[0u8; super::frame::HEADER]);
        self.encode_body(out);
    }

    fn encode_body(&self, out: &mut Vec<u8>) {
        match self {
            Response::Predict { model_version, outputs } => {
                out.push(0);
                put_u64(out, *model_version);
                put_u32(out, outputs.len() as u32);
                for (name, t) in outputs {
                    put_str(out, name);
                    put_out_tensor(out, t);
                }
            }
            Response::Classify { model_version, classes, log_probs } => {
                out.push(1);
                put_u64(out, *model_version);
                put_u32(out, classes.len() as u32);
                for c in classes {
                    out.extend_from_slice(&c.to_le_bytes());
                }
                put_u32(out, log_probs.len() as u32);
                for row in log_probs {
                    put_f32s(out, row);
                }
            }
            Response::Regress { model_version, values } => {
                out.push(2);
                put_u64(out, *model_version);
                put_f32s(out, values);
            }
            Response::Lookup { values } => {
                out.push(3);
                match values {
                    Some(v) => {
                        out.push(1);
                        put_f32s(out, v);
                    }
                    None => out.push(0),
                }
            }
            Response::Ack => out.push(4),
            Response::ModelStatus { versions } => {
                out.push(5);
                put_u32(out, versions.len() as u32);
                for (v, state) in versions {
                    put_u64(out, *v);
                    put_str(out, state);
                }
            }
            Response::Status { text } => {
                out.push(6);
                put_str(out, text);
            }
            Response::Pong => out.push(7),
            Response::Metrics { samples } => {
                out.push(10);
                put_u32(out, samples.len() as u32);
                for (name, value) in samples {
                    put_str(out, name);
                    // f64 as raw bits: exact roundtrip, no formatting.
                    put_u64(out, value.to_bits());
                }
            }
            Response::ModelMetadata { model, versions } => {
                out.push(8);
                put_str(out, model);
                put_u32(out, versions.len() as u32);
                for vm in versions {
                    put_version_metadata(out, vm);
                }
            }
            Response::MultiInference { model_version, results } => {
                out.push(9);
                put_u64(out, *model_version);
                put_u32(out, results.len() as u32);
                for (name, head) in results {
                    put_str(out, name);
                    put_head_result(out, head);
                }
            }
            Response::Error { kind, message } => {
                out.push(255);
                out.push(kind.code());
                put_str(out, message);
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Response> {
        let mut r = Reader::new(buf);
        let resp = match r.u8()? {
            0 => {
                let model_version = r.u64()?;
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("implausible output count {n}");
                }
                let mut outputs = Vec::with_capacity(n);
                for _ in 0..n {
                    let name = r.str()?;
                    outputs.push((name, read_out_tensor(&mut r)?));
                }
                Response::Predict { model_version, outputs }
            }
            1 => {
                let model_version = r.u64()?;
                let n = r.u32()? as usize;
                let raw = r.bytes(n * 4)?;
                let classes = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let m = r.u32()? as usize;
                if m > 1 << 20 {
                    bail!("implausible row count {m}");
                }
                let mut log_probs = Vec::with_capacity(m);
                for _ in 0..m {
                    log_probs.push(r.f32s()?);
                }
                Response::Classify { model_version, classes, log_probs }
            }
            2 => Response::Regress { model_version: r.u64()?, values: r.f32s()? },
            3 => Response::Lookup {
                values: match r.u8()? {
                    0 => None,
                    1 => Some(r.f32s()?),
                    t => bail!("bad option tag {t}"),
                },
            },
            4 => Response::Ack,
            5 => {
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("implausible version count {n}");
                }
                let mut versions = Vec::with_capacity(n);
                for _ in 0..n {
                    versions.push((r.u64()?, r.str()?));
                }
                Response::ModelStatus { versions }
            }
            6 => Response::Status { text: r.str()? },
            7 => Response::Pong,
            10 => {
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("implausible sample count {n}");
                }
                let mut samples = Vec::with_capacity(n);
                for _ in 0..n {
                    let name = r.str()?;
                    samples.push((name, f64::from_bits(r.u64()?)));
                }
                Response::Metrics { samples }
            }
            8 => {
                let model = r.str()?;
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("implausible version count {n}");
                }
                let mut versions = Vec::with_capacity(n);
                for _ in 0..n {
                    versions.push(r.version_metadata()?);
                }
                Response::ModelMetadata { model, versions }
            }
            9 => {
                let model_version = r.u64()?;
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("implausible result count {n}");
                }
                let mut results = Vec::with_capacity(n);
                for _ in 0..n {
                    let name = r.str()?;
                    results.push((name, r.head_result()?));
                }
                Response::MultiInference { model_version, results }
            }
            255 => Response::Error { kind: ErrorKind::from_code(r.u8()?), message: r.str()? },
            t => bail!("unknown response tag {t}"),
        };
        r.done()?;
        Ok(resp)
    }

    /// Build an error response from an `anyhow` error, carrying its
    /// kind onto the wire (plain errors classify as `Internal`).
    pub fn error(e: &anyhow::Error) -> Response {
        Response::Error { kind: ErrorKind::of(e), message: e.to_string() }
    }

    /// Convert an error response to a Result. The kind survives the
    /// conversion: `ErrorKind::of` on the returned error recovers it.
    pub fn into_result(self) -> Result<Response> {
        match self {
            Response::Error { kind, message } => Err(kind.err(message)),
            other => Ok(other),
        }
    }

    /// Hand output-tensor storage back to the global pools. Called by
    /// the server's connection loop after serialization, when the
    /// response holds the sole reference: the pool declines anything
    /// still shared or not class-sized, so this is always safe.
    pub fn recycle_buffers(self) {
        if let Response::Predict { outputs, .. } = self {
            crate::inference::predict::recycle_out_tensors(
                outputs.into_iter().map(|(_, t)| t).collect(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::example::Feature;
    use crate::runtime::artifacts::ArtifactSpec;

    fn roundtrip_req(req: Request) {
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
    }

    fn roundtrip_resp(resp: Response) {
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
    }

    fn full_spec() -> ModelSpec {
        ModelSpec { name: "m".into(), version: Some(3), label: None }
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(Request::Predict {
            spec: full_spec(),
            signature: "serving_default".into(),
            inputs: vec![
                ("x".into(), Tensor::matrix(vec![vec![1.0, 2.0]]).unwrap()),
                ("mask".into(), Tensor::zeros(vec![2, 3, 4])),
            ],
        });
        roundtrip_req(Request::predict("m", None, Tensor::zeros(vec![2, 3, 4])));
        roundtrip_req(Request::Predict {
            spec: ModelSpec::with_label("m", "canary"),
            signature: String::new(),
            inputs: vec![("x".into(), Tensor::vec(vec![1.0]))],
        });
        roundtrip_req(Request::classify(
            "c",
            None,
            vec![
                Example::new().with("x", Feature::Floats(vec![1.0])),
                Example::new().with("y", Feature::Ints(vec![-5])),
            ],
        ));
        roundtrip_req(Request::Classify {
            spec: ModelSpec::with_label("c", "stable"),
            signature: "heads".into(),
            examples: vec![Example::new()],
        });
        roundtrip_req(Request::regress("r", Some(1), vec![Example::new()]));
        roundtrip_req(Request::MultiInference {
            spec: ModelSpec::latest("m"),
            tasks: vec![
                InferenceTask::classify("classify"),
                InferenceTask::regress("regress"),
            ],
            examples: vec![Example::new().with("x", Feature::Floats(vec![0.5; 4]))],
        });
        roundtrip_req(Request::GetModelMetadata { spec: ModelSpec::latest("m") });
        roundtrip_req(Request::GetModelMetadata {
            spec: ModelSpec::with_label("m", "canary"),
        });
        roundtrip_req(Request::SetVersionLabel {
            model: "m".into(),
            label: "canary".into(),
            version: 7,
        });
        roundtrip_req(Request::DeleteVersionLabel {
            model: "m".into(),
            label: "canary".into(),
        });
        roundtrip_req(Request::Lookup { table: "t".into(), key: "k".into() });
        roundtrip_req(Request::SetAspired { model: "m".into(), versions: vec![1, 2, 9] });
        roundtrip_req(Request::SetAspired { model: "m".into(), versions: vec![] });
        roundtrip_req(Request::ModelStatus { model: "m".into() });
        roundtrip_req(Request::Status);
        roundtrip_req(Request::Metrics);
        roundtrip_req(Request::SetRolloutStatus {
            model: "m".into(),
            status: "rolled_back: error-rate 0.41 > 0.10".into(),
        });
        roundtrip_req(Request::SetRolloutStatus { model: "m".into(), status: String::new() });
        roundtrip_req(Request::Ping);
        roundtrip_req(
            Request::predict("m", None, Tensor::zeros(vec![2, 4])).with_deadline_ms(150),
        );
    }

    #[test]
    fn deadline_envelope_rules() {
        // Re-wrapping replaces the deadline, never nests.
        let req = Request::Ping.with_deadline_ms(10).with_deadline_ms(20);
        match &req {
            Request::WithDeadline { deadline_ms, inner } => {
                assert_eq!(*deadline_ms, 20);
                assert_eq!(**inner, Request::Ping);
            }
            other => panic!("unexpected {other:?}"),
        }
        roundtrip_req(req);
        // A hand-crafted nested envelope is rejected on decode.
        let mut wire = vec![12u8];
        wire.extend_from_slice(&5u64.to_le_bytes());
        wire.extend_from_slice(&Request::Ping.with_deadline_ms(1).encode());
        let err = Request::decode(&wire).unwrap_err();
        assert!(err.to_string().contains("nested"), "{err}");
        // Truncation at every cut errors cleanly.
        let full = Request::classify("c", Some(2), vec![Example::new()])
            .with_deadline_ms(99)
            .encode();
        for cut in 0..full.len() {
            assert!(Request::decode(&full[..cut]).is_err(), "envelope cut={cut}");
        }
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(Response::Predict {
            model_version: 2,
            outputs: vec![
                (
                    "log_probs".into(),
                    OutTensor::F32(Tensor::matrix(vec![vec![0.5, -1.5]]).unwrap()),
                ),
                ("class".into(), OutTensor::I32(TensorI32::new(vec![1], vec![3]).unwrap())),
            ],
        });
        roundtrip_resp(Response::Classify {
            model_version: 1,
            classes: vec![0, 3, -1],
            log_probs: vec![vec![-0.1, -2.0], vec![], vec![1.0]],
        });
        roundtrip_resp(Response::Regress { model_version: 1, values: vec![1.5] });
        roundtrip_resp(Response::MultiInference {
            model_version: 4,
            results: vec![
                (
                    "classify".into(),
                    HeadResult::Classify {
                        classes: vec![1, 0],
                        log_probs: vec![vec![-0.5, -1.0], vec![-0.1, -2.3]],
                    },
                ),
                ("regress".into(), HeadResult::Regress { values: vec![0.25, -4.0] }),
            ],
        });
        let spec = ArtifactSpec::synthetic_multi_head("syn", 2, 8, 3);
        roundtrip_resp(Response::ModelMetadata {
            model: "syn".into(),
            versions: vec![
                VersionMetadata {
                    version: 1,
                    state: "ready".into(),
                    labels: vec!["stable".into()],
                    signatures: spec
                        .signatures
                        .iter()
                        .map(|(k, v)| (k.clone(), v.clone()))
                        .collect(),
                },
                VersionMetadata {
                    version: 2,
                    state: "loading".into(),
                    labels: vec![],
                    signatures: vec![],
                },
            ],
        });
        roundtrip_resp(Response::Lookup { values: Some(vec![1.0, 2.0]) });
        roundtrip_resp(Response::Lookup { values: None });
        roundtrip_resp(Response::Ack);
        roundtrip_resp(Response::ModelStatus {
            versions: vec![(1, "ready".into()), (2, "loading".into())],
        });
        roundtrip_resp(Response::Status { text: "ok\nqps 12".into() });
        // Metric samples: f64 bit-exact across the wire, including
        // values a decimal formatter would mangle.
        roundtrip_resp(Response::Metrics {
            samples: vec![
                ("batch.m.lane_depth".into(), 3.0),
                ("batch.m.queue_delay_ns.p99".into(), 0.1 + 0.2),
                ("admission.shed".into(), f64::MAX),
            ],
        });
        roundtrip_resp(Response::Metrics { samples: vec![] });
        roundtrip_resp(Response::Pong);
        for kind in [
            ErrorKind::NotFound,
            ErrorKind::InvalidArgument,
            ErrorKind::FailedPrecondition,
            ErrorKind::DeadlineExceeded,
            ErrorKind::Unavailable,
            ErrorKind::Internal,
        ] {
            roundtrip_resp(Response::Error { kind, message: "boom".into() });
        }
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let mut buf = Vec::new();
        Request::Ping.encode_into(&mut buf);
        assert_eq!(Request::decode(&buf).unwrap(), Request::Ping);
        buf.reserve(1024);
        let cap = buf.capacity();
        Request::ModelStatus { model: "m".into() }.encode_into(&mut buf);
        assert_eq!(buf.capacity(), cap, "encode_into reallocated");
        assert_eq!(
            Request::decode(&buf).unwrap(),
            Request::ModelStatus { model: "m".into() }
        );
        let mut rbuf = Vec::new();
        Response::Pong.encode_into(&mut rbuf);
        assert_eq!(Response::decode(&rbuf).unwrap(), Response::Pong);
    }

    #[test]
    fn framed_encoding_reserves_header() {
        use crate::rpc::frame::{read_frame, write_framed, HEADER};
        let req = Request::predict("m", Some(1), Tensor::zeros(vec![2, 4]));
        let mut framed = Vec::new();
        req.encode_framed_into(&mut framed);
        // Body after the header matches the plain encoding.
        assert_eq!(&framed[HEADER..], &req.encode()[..]);
        // One write_framed call produces a stream read_frame understands.
        let mut wire = Vec::new();
        write_framed(&mut wire, &mut framed).unwrap();
        let payload = read_frame(&mut std::io::Cursor::new(wire)).unwrap().unwrap();
        assert_eq!(Request::decode(&payload).unwrap(), req);
        // Response side too.
        let resp = Response::Status { text: "ok".into() };
        let mut framed = Vec::new();
        resp.encode_framed_into(&mut framed);
        let mut wire = Vec::new();
        write_framed(&mut wire, &mut framed).unwrap();
        let payload = read_frame(&mut std::io::Cursor::new(wire)).unwrap().unwrap();
        assert_eq!(Response::decode(&payload).unwrap(), resp);
    }

    #[test]
    fn decoded_tensors_use_pooled_class_storage() {
        // The decode path writes into a dedicated pool-class buffer
        // at offset 0 (so the serving layer can recycle it after batch
        // assembly or inference consumes it) — f32 and i32 alike.
        let req = Request::Predict {
            spec: ModelSpec::latest("m"),
            signature: String::new(),
            inputs: vec![(
                "x".into(),
                Tensor::matrix(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap(),
            )],
        };
        match Request::decode(&req.encode()).unwrap() {
            Request::Predict { inputs, .. } => {
                let input = &inputs[0].1;
                assert_eq!(input.data(), &[1.0, 2.0, 3.0, 4.0]);
                let class = crate::util::pool::size_class(input.len());
                assert_eq!(input.storage().len(), class);
                assert_eq!(input.data().as_ptr(), input.storage().as_ptr());
            }
            other => panic!("unexpected {other:?}"),
        }
        let resp = Response::Predict {
            model_version: 1,
            outputs: vec![(
                "class".into(),
                OutTensor::I32(TensorI32::new(vec![3], vec![1, 2, 3]).unwrap()),
            )],
        };
        match Response::decode(&resp.encode()).unwrap() {
            Response::Predict { outputs, .. } => {
                let t = outputs[0].1.as_i32().unwrap().clone();
                assert_eq!(t.data(), &[1, 2, 3]);
                // Recycling the decoded i32 tensor lands it in the
                // global i32 pool (sole owner, class-sized).
                let before = BufferPool::global_i32().stats().recycled;
                drop(outputs);
                t.recycle_into(&BufferPool::global_i32());
                // >= rather than == : other tests share the global pool.
                assert!(BufferPool::global_i32().stats().recycled >= before + 1);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn predict_response_recycles_into_pools() {
        let f32_before = BufferPool::global().stats().recycled;
        let t = Tensor::build_with(vec![4, 4], &BufferPool::global(), |b| b.fill(1.0));
        let resp = Response::Predict {
            model_version: 1,
            outputs: vec![("y".into(), OutTensor::F32(t))],
        };
        let mut buf = Vec::new();
        resp.encode_framed_into(&mut buf);
        resp.recycle_buffers();
        // >= rather than == : other tests share the global pool.
        assert!(BufferPool::global().stats().recycled >= f32_before + 1);
        // Non-predict responses are a no-op.
        Response::Pong.recycle_buffers();
    }

    #[test]
    fn error_into_result() {
        assert!(Response::Pong.into_result().is_ok());
        let err = Response::Error {
            kind: ErrorKind::NotFound,
            message: "nope".into(),
        }
        .into_result()
        .unwrap_err();
        assert!(err.to_string().contains("nope"));
        // The typed kind crosses the wire and survives into_result.
        assert_eq!(ErrorKind::of(&err), ErrorKind::NotFound);
    }

    #[test]
    fn error_kind_truncation_and_unknown_codes() {
        // Truncating the kind byte or the message must error cleanly.
        let full = Response::Error {
            kind: ErrorKind::FailedPrecondition,
            message: "drained".into(),
        }
        .encode();
        for cut in 0..full.len() {
            assert!(Response::decode(&full[..cut]).is_err(), "error cut={cut}");
        }
        // An unknown kind code from a newer peer degrades to Internal.
        let mut wire = full.clone();
        wire[1] = 77;
        assert_eq!(
            Response::decode(&wire).unwrap(),
            Response::Error { kind: ErrorKind::Internal, message: "drained".into() }
        );
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[99]).is_err());
        assert!(Response::decode(&[42]).is_err());
        // trailing bytes
        let mut buf = Request::Ping.encode();
        buf.push(0);
        assert!(Request::decode(&buf).is_err());
        // truncation at every prefix must error, not panic — exercised
        // over the most structure-heavy request and response frames.
        let full = Request::MultiInference {
            spec: ModelSpec::with_label("model", "canary"),
            tasks: vec![InferenceTask::classify("c"), InferenceTask::regress("r")],
            examples: vec![Example::new().with("x", Feature::Floats(vec![1.0, 2.0]))],
        }
        .encode();
        for cut in 0..full.len() {
            assert!(Request::decode(&full[..cut]).is_err(), "request cut={cut}");
        }
        let full = Request::DeleteVersionLabel { model: "m".into(), label: "stable".into() }
            .encode();
        for cut in 0..full.len() {
            assert!(Request::decode(&full[..cut]).is_err(), "delete-label cut={cut}");
        }
        let full = Response::Metrics {
            samples: vec![("batch.m.lane_depth".into(), 2.5)],
        }
        .encode();
        for cut in 0..full.len() {
            assert!(Response::decode(&full[..cut]).is_err(), "metrics cut={cut}");
        }
        let spec = ArtifactSpec::synthetic_classifier("s", 1, 4, 2);
        let full = Response::ModelMetadata {
            model: "s".into(),
            versions: vec![VersionMetadata {
                version: 1,
                state: "ready".into(),
                labels: vec!["stable".into()],
                signatures: spec
                    .signatures
                    .iter()
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect(),
            }],
        }
        .encode();
        for cut in 0..full.len() {
            assert!(Response::decode(&full[..cut]).is_err(), "response cut={cut}");
        }
    }
}
