//! Wire messages and their binary codecs.
//!
//! Inference messages mirror §2.2's three APIs (Predict / Classify /
//! Regress) plus a BananaFlow table lookup; admin messages carry the
//! TFS² control plane (SetAspired from the Synchronizer, ModelStatus
//! back). Codec style matches `inference::example`: u8 tags + u32 le
//! length prefixes, no self-description.
//!
//! Hot-path codec properties: request tensors decode **straight into
//! pooled tensor storage** (wire bytes → the buffer the serving layer
//! will read, no intermediate `Vec<f32>`), responses encode from
//! tensor views without materializing owned copies, and
//! [`Request::encode_into`]/[`Response::encode_into`] let connection
//! loops reuse one scratch buffer across frames.

use crate::base::tensor::{Tensor, TensorI32};
use crate::util::pool::BufferPool;
use crate::inference::example::Example;
use crate::runtime::pjrt::OutTensor;
use anyhow::{anyhow, bail, Result};

/// A request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Predict { model: String, version: Option<u64>, input: Tensor },
    Classify { model: String, version: Option<u64>, examples: Vec<Example> },
    Regress { model: String, version: Option<u64>, examples: Vec<Example> },
    Lookup { table: String, key: String },
    /// Admin: full aspired-version set for one servable (RPC source).
    SetAspired { model: String, versions: Vec<u64> },
    /// Admin: which versions of `model` are in which state?
    ModelStatus { model: String },
    /// Admin: server metrics/status dump.
    Status,
    /// Liveness probe / no-op (used by benches to measure RPC floor).
    Ping,
}

/// A response frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Predict { model_version: u64, outputs: Vec<OutTensor> },
    Classify { model_version: u64, classes: Vec<i32>, log_probs: Vec<Vec<f32>> },
    Regress { model_version: u64, values: Vec<f32> },
    Lookup { values: Option<Vec<f32>> },
    Ack,
    ModelStatus { versions: Vec<(u64, String)> },
    Status { text: String },
    Pong,
    Error { message: String },
}

// ------------------------------------------------------------ helpers

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_opt_version(out: &mut Vec<u8>, v: Option<u64>) {
    match v {
        Some(v) => {
            out.push(1);
            put_u64(out, v);
        }
        None => out.push(0),
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    put_u32(out, t.shape().len() as u32);
    for &d in t.shape() {
        put_u32(out, d as u32);
    }
    put_f32s(out, t.data());
}

fn put_examples(out: &mut Vec<u8>, examples: &[Example]) {
    put_u32(out, examples.len() as u32);
    for ex in examples {
        let enc = ex.encode();
        put_u32(out, enc.len() as u32);
        out.extend_from_slice(&enc);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn u8(&mut self) -> Result<u8> {
        let v = *self.buf.get(self.pos).ok_or_else(|| anyhow!("truncated u8"))?;
        self.pos += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        if end > self.buf.len() {
            bail!("truncated u32");
        }
        let v = u32::from_le_bytes(self.buf[self.pos..end].try_into().unwrap());
        self.pos = end;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64> {
        let end = self.pos + 8;
        if end > self.buf.len() {
            bail!("truncated u64");
        }
        let v = u64::from_le_bytes(self.buf[self.pos..end].try_into().unwrap());
        self.pos = end;
        Ok(v)
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos + n;
        if end > self.buf.len() {
            bail!("truncated bytes({n})");
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > 1 << 20 {
            bail!("implausible string length {n}");
        }
        Ok(std::str::from_utf8(self.bytes(n)?)?.to_string())
    }

    fn opt_version(&mut self) -> Result<Option<u64>> {
        Ok(match self.u8()? {
            0 => None,
            1 => Some(self.u64()?),
            t => bail!("bad option tag {t}"),
        })
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Decode a tensor by writing wire bytes directly into pooled
    /// storage — the buffer handed to the serving layer, zero
    /// intermediate copies.
    fn tensor(&mut self) -> Result<Tensor> {
        let rank = self.u32()? as usize;
        if rank > 8 {
            bail!("implausible rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u32()? as usize);
        }
        let want = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| anyhow!("tensor shape {shape:?} overflows"))?;
        let n = self.u32()? as usize;
        if n != want {
            bail!("tensor data length {n} != shape {shape:?} product {want}");
        }
        let raw = self.bytes(n * 4)?;
        Ok(Tensor::build_with(shape, &BufferPool::global(), |buf| {
            for (dst, src) in buf.iter_mut().zip(raw.chunks_exact(4)) {
                *dst = f32::from_le_bytes(src.try_into().unwrap());
            }
        }))
    }

    fn examples(&mut self) -> Result<Vec<Example>> {
        let n = self.u32()? as usize;
        if n > 1 << 20 {
            bail!("implausible example count {n}");
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let len = self.u32()? as usize;
            out.push(Example::decode(self.bytes(len)?)?);
        }
        Ok(out)
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("trailing bytes in message");
        }
        Ok(())
    }
}

// -------------------------------------------------------------- codecs

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Encode into a caller-owned scratch buffer (cleared first), so
    /// connection loops reuse one allocation across requests.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        match self {
            Request::Predict { model, version, input } => {
                out.push(0);
                put_str(out, model);
                put_opt_version(out, *version);
                put_tensor(out, input);
            }
            Request::Classify { model, version, examples } => {
                out.push(1);
                put_str(out, model);
                put_opt_version(out, *version);
                put_examples(out, examples);
            }
            Request::Regress { model, version, examples } => {
                out.push(2);
                put_str(out, model);
                put_opt_version(out, *version);
                put_examples(out, examples);
            }
            Request::Lookup { table, key } => {
                out.push(3);
                put_str(out, table);
                put_str(out, key);
            }
            Request::SetAspired { model, versions } => {
                out.push(4);
                put_str(out, model);
                put_u32(out, versions.len() as u32);
                for v in versions {
                    put_u64(out, *v);
                }
            }
            Request::ModelStatus { model } => {
                out.push(5);
                put_str(out, model);
            }
            Request::Status => out.push(6),
            Request::Ping => out.push(7),
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Request> {
        let mut r = Reader::new(buf);
        let req = match r.u8()? {
            0 => Request::Predict {
                model: r.str()?,
                version: r.opt_version()?,
                input: r.tensor()?,
            },
            1 => Request::Classify {
                model: r.str()?,
                version: r.opt_version()?,
                examples: r.examples()?,
            },
            2 => Request::Regress {
                model: r.str()?,
                version: r.opt_version()?,
                examples: r.examples()?,
            },
            3 => Request::Lookup { table: r.str()?, key: r.str()? },
            4 => {
                let model = r.str()?;
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("implausible version count {n}");
                }
                let mut versions = Vec::with_capacity(n);
                for _ in 0..n {
                    versions.push(r.u64()?);
                }
                Request::SetAspired { model, versions }
            }
            5 => Request::ModelStatus { model: r.str()? },
            6 => Request::Status,
            7 => Request::Ping,
            t => bail!("unknown request tag {t}"),
        };
        r.done()?;
        Ok(req)
    }
}

fn put_out_tensor(out: &mut Vec<u8>, t: &OutTensor) {
    match t {
        OutTensor::F32(t) => {
            out.push(0);
            put_tensor(out, t);
        }
        OutTensor::I32(t) => {
            out.push(1);
            put_u32(out, t.shape().len() as u32);
            for &d in t.shape() {
                put_u32(out, d as u32);
            }
            put_u32(out, t.data().len() as u32);
            for x in t.data() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

fn read_out_tensor(r: &mut Reader<'_>) -> Result<OutTensor> {
    Ok(match r.u8()? {
        0 => OutTensor::F32(r.tensor()?),
        1 => {
            let rank = r.u32()? as usize;
            if rank > 8 {
                bail!("implausible rank {rank}");
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r.u32()? as usize);
            }
            let n = r.u32()? as usize;
            let raw = r.bytes(n * 4)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            OutTensor::I32(TensorI32::new(shape, data)?)
        }
        t => bail!("unknown tensor tag {t}"),
    })
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Encode into a caller-owned scratch buffer (cleared first), so
    /// connection loops reuse one allocation across responses.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        match self {
            Response::Predict { model_version, outputs } => {
                out.push(0);
                put_u64(out, *model_version);
                put_u32(out, outputs.len() as u32);
                for t in outputs {
                    put_out_tensor(out, t);
                }
            }
            Response::Classify { model_version, classes, log_probs } => {
                out.push(1);
                put_u64(out, *model_version);
                put_u32(out, classes.len() as u32);
                for c in classes {
                    out.extend_from_slice(&c.to_le_bytes());
                }
                put_u32(out, log_probs.len() as u32);
                for row in log_probs {
                    put_f32s(out, row);
                }
            }
            Response::Regress { model_version, values } => {
                out.push(2);
                put_u64(out, *model_version);
                put_f32s(out, values);
            }
            Response::Lookup { values } => {
                out.push(3);
                match values {
                    Some(v) => {
                        out.push(1);
                        put_f32s(out, v);
                    }
                    None => out.push(0),
                }
            }
            Response::Ack => out.push(4),
            Response::ModelStatus { versions } => {
                out.push(5);
                put_u32(out, versions.len() as u32);
                for (v, state) in versions {
                    put_u64(out, *v);
                    put_str(out, state);
                }
            }
            Response::Status { text } => {
                out.push(6);
                put_str(out, text);
            }
            Response::Pong => out.push(7),
            Response::Error { message } => {
                out.push(255);
                put_str(out, message);
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Response> {
        let mut r = Reader::new(buf);
        let resp = match r.u8()? {
            0 => {
                let model_version = r.u64()?;
                let n = r.u32()? as usize;
                let mut outputs = Vec::with_capacity(n);
                for _ in 0..n {
                    outputs.push(read_out_tensor(&mut r)?);
                }
                Response::Predict { model_version, outputs }
            }
            1 => {
                let model_version = r.u64()?;
                let n = r.u32()? as usize;
                let raw = r.bytes(n * 4)?;
                let classes = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let m = r.u32()? as usize;
                if m > 1 << 20 {
                    bail!("implausible row count {m}");
                }
                let mut log_probs = Vec::with_capacity(m);
                for _ in 0..m {
                    log_probs.push(r.f32s()?);
                }
                Response::Classify { model_version, classes, log_probs }
            }
            2 => Response::Regress { model_version: r.u64()?, values: r.f32s()? },
            3 => Response::Lookup {
                values: match r.u8()? {
                    0 => None,
                    1 => Some(r.f32s()?),
                    t => bail!("bad option tag {t}"),
                },
            },
            4 => Response::Ack,
            5 => {
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("implausible version count {n}");
                }
                let mut versions = Vec::with_capacity(n);
                for _ in 0..n {
                    versions.push((r.u64()?, r.str()?));
                }
                Response::ModelStatus { versions }
            }
            6 => Response::Status { text: r.str()? },
            7 => Response::Pong,
            255 => Response::Error { message: r.str()? },
            t => bail!("unknown response tag {t}"),
        };
        r.done()?;
        Ok(resp)
    }

    /// Convert an error response to a Result.
    pub fn into_result(self) -> Result<Response> {
        match self {
            Response::Error { message } => bail!("{message}"),
            other => Ok(other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::example::Feature;

    fn roundtrip_req(req: Request) {
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
    }

    fn roundtrip_resp(resp: Response) {
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(Request::Predict {
            model: "m".into(),
            version: Some(3),
            input: Tensor::matrix(vec![vec![1.0, 2.0]]).unwrap(),
        });
        roundtrip_req(Request::Predict {
            model: "m".into(),
            version: None,
            input: Tensor::zeros(vec![2, 3, 4]),
        });
        roundtrip_req(Request::Classify {
            model: "c".into(),
            version: None,
            examples: vec![
                Example::new().with("x", Feature::Floats(vec![1.0])),
                Example::new().with("y", Feature::Ints(vec![-5])),
            ],
        });
        roundtrip_req(Request::Regress {
            model: "r".into(),
            version: Some(1),
            examples: vec![Example::new()],
        });
        roundtrip_req(Request::Lookup { table: "t".into(), key: "k".into() });
        roundtrip_req(Request::SetAspired { model: "m".into(), versions: vec![1, 2, 9] });
        roundtrip_req(Request::SetAspired { model: "m".into(), versions: vec![] });
        roundtrip_req(Request::ModelStatus { model: "m".into() });
        roundtrip_req(Request::Status);
        roundtrip_req(Request::Ping);
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(Response::Predict {
            model_version: 2,
            outputs: vec![
                OutTensor::F32(Tensor::matrix(vec![vec![0.5, -1.5]]).unwrap()),
                OutTensor::I32(TensorI32::new(vec![1], vec![3]).unwrap()),
            ],
        });
        roundtrip_resp(Response::Classify {
            model_version: 1,
            classes: vec![0, 3, -1],
            log_probs: vec![vec![-0.1, -2.0], vec![], vec![1.0]],
        });
        roundtrip_resp(Response::Regress { model_version: 1, values: vec![1.5] });
        roundtrip_resp(Response::Lookup { values: Some(vec![1.0, 2.0]) });
        roundtrip_resp(Response::Lookup { values: None });
        roundtrip_resp(Response::Ack);
        roundtrip_resp(Response::ModelStatus {
            versions: vec![(1, "ready".into()), (2, "loading".into())],
        });
        roundtrip_resp(Response::Status { text: "ok\nqps 12".into() });
        roundtrip_resp(Response::Pong);
        roundtrip_resp(Response::Error { message: "boom".into() });
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let mut buf = Vec::new();
        Request::Ping.encode_into(&mut buf);
        assert_eq!(Request::decode(&buf).unwrap(), Request::Ping);
        buf.reserve(1024);
        let cap = buf.capacity();
        Request::ModelStatus { model: "m".into() }.encode_into(&mut buf);
        assert_eq!(buf.capacity(), cap, "encode_into reallocated");
        assert_eq!(
            Request::decode(&buf).unwrap(),
            Request::ModelStatus { model: "m".into() }
        );
        let mut rbuf = Vec::new();
        Response::Pong.encode_into(&mut rbuf);
        assert_eq!(Response::decode(&rbuf).unwrap(), Response::Pong);
    }

    #[test]
    fn decoded_tensor_uses_pooled_class_storage() {
        // The decode path writes into a dedicated pool-class buffer
        // at offset 0 (so the serving layer can recycle it after batch
        // assembly or inference consumes it).
        let req = Request::Predict {
            model: "m".into(),
            version: None,
            input: Tensor::matrix(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap(),
        };
        match Request::decode(&req.encode()).unwrap() {
            Request::Predict { input, .. } => {
                assert_eq!(input.data(), &[1.0, 2.0, 3.0, 4.0]);
                let class = crate::util::pool::size_class(input.len());
                assert_eq!(input.storage().len(), class);
                assert_eq!(input.data().as_ptr(), input.storage().as_ptr());
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn error_into_result() {
        assert!(Response::Pong.into_result().is_ok());
        let err = Response::Error { message: "nope".into() }.into_result();
        assert!(err.unwrap_err().to_string().contains("nope"));
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[99]).is_err());
        assert!(Response::decode(&[42]).is_err());
        // trailing bytes
        let mut buf = Request::Ping.encode();
        buf.push(0);
        assert!(Request::decode(&buf).is_err());
        // truncation at every prefix must error, not panic
        let full = Request::Predict {
            model: "model".into(),
            version: Some(1),
            input: Tensor::matrix(vec![vec![1.0, 2.0]]).unwrap(),
        }
        .encode();
        for cut in 0..full.len() {
            assert!(Request::decode(&full[..cut]).is_err(), "cut={cut}");
        }
    }
}
