//! Threaded RPC server: accept loop + one handler thread per
//! connection, framed request/response, graceful shutdown.

use super::frame::{read_frame_into, write_framed};
use super::proto::{Request, Response};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Request handler: pure function from request to response. Handlers
/// run on connection threads; anything shared must be Sync.
pub type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync>;

pub struct RpcServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    requests_served: Arc<AtomicU64>,
}

impl RpcServer {
    /// Bind and start serving `handler` on `addr` (use port 0 for an
    /// ephemeral port; read it back from [`RpcServer::addr`]).
    pub fn start(addr: &str, handler: Handler) -> anyhow::Result<Arc<Self>> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let requests_served = Arc::new(AtomicU64::new(0));

        let accept_shutdown = Arc::clone(&shutdown);
        let accept_counter = Arc::clone(&requests_served);
        let accept_thread = std::thread::Builder::new()
            .name(format!("rpc-accept-{}", local.port()))
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    match stream {
                        Ok(stream) => {
                            let handler = Arc::clone(&handler);
                            let counter = Arc::clone(&accept_counter);
                            let sd = Arc::clone(&accept_shutdown);
                            let _ = std::thread::Builder::new()
                                .name("rpc-conn".to_string())
                                .spawn(move || {
                                    Self::serve_connection(stream, handler, counter, sd)
                                });
                        }
                        Err(e) => {
                            crate::log_warn!("accept error: {e}");
                        }
                    }
                }
            })?;

        crate::log_info!("rpc server listening on {local}");
        Ok(Arc::new(RpcServer {
            addr: local,
            shutdown,
            accept_thread: Mutex::new(Some(accept_thread)),
            requests_served,
        }))
    }

    fn serve_connection(
        mut stream: TcpStream,
        handler: Handler,
        counter: Arc<AtomicU64>,
        shutdown: Arc<AtomicBool>,
    ) {
        let _ = stream.set_nodelay(true);
        // Per-connection scratch: frame payloads land in `payload` and
        // responses serialize into `encoded` — both reuse their
        // capacity across every request on this connection.
        let mut payload = Vec::new();
        let mut encoded = Vec::new();
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            match read_frame_into(&mut stream, &mut payload) {
                Ok(true) => {}
                Ok(false) => return, // client hung up
                Err(e) => {
                    crate::log_debug!("connection read error: {e}");
                    return;
                }
            }
            let response = match Request::decode(&payload) {
                Ok(req) => handler(req),
                Err(e) => Response::Error {
                    kind: crate::base::error::ErrorKind::InvalidArgument,
                    message: format!("bad request: {e}"),
                },
            };
            counter.fetch_add(1, Ordering::Relaxed);
            // Header bytes are reserved inside the scratch buffer, so
            // the reply is ONE write syscall; once the bytes are in
            // `encoded`, sole-owner output tensors go back to the pool.
            response.encode_framed_into(&mut encoded);
            response.recycle_buffers();
            if let Err(e) = write_framed(&mut stream, &mut encoded) {
                crate::log_debug!("connection write error: {e}");
                return;
            }
        }
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn requests_served(&self) -> u64 {
        self.requests_served.load(Ordering::Relaxed)
    }

    /// Stop accepting. In-flight connections finish their current
    /// request and exit on next read.
    pub fn stop(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Poke the accept loop awake.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::client::RpcClient;
    use crate::rpc::frame::{read_frame, write_frame};

    fn echo_server() -> Arc<RpcServer> {
        RpcServer::start(
            "127.0.0.1:0",
            Arc::new(|req| match req {
                Request::Ping => Response::Pong,
                Request::Status => Response::Status { text: "ok".into() },
                _ => Response::Error {
                    kind: crate::base::error::ErrorKind::Internal,
                    message: "unsupported".into(),
                },
            }),
        )
        .unwrap()
    }

    #[test]
    fn ping_pong() {
        let server = echo_server();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
        assert_eq!(
            client.call(&Request::Status).unwrap(),
            Response::Status { text: "ok".into() }
        );
        assert_eq!(server.requests_served(), 2);
    }

    #[test]
    fn concurrent_clients() {
        let server = echo_server();
        let addr = server.addr().to_string();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = RpcClient::connect(&addr).unwrap();
                    for _ in 0..50 {
                        assert_eq!(c.call(&Request::Ping).unwrap(), Response::Pong);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.requests_served(), 400);
    }

    #[test]
    fn malformed_request_gets_error_response() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        write_frame(&mut stream, &[42, 42, 42]).unwrap();
        let resp = Response::decode(&read_frame(&mut stream).unwrap().unwrap()).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
    }

    #[test]
    fn stop_then_connect_fails_eventually() {
        let server = echo_server();
        let addr = server.addr();
        server.stop();
        // The listener socket is closed after stop; new connections
        // must fail (immediately or after the OS backlog drains).
        std::thread::sleep(std::time::Duration::from_millis(50));
        let ok = TcpStream::connect(addr)
            .map(|mut s| {
                write_frame(&mut s, &Request::Ping.encode()).ok();
                read_frame(&mut s).ok().flatten().is_some()
            })
            .unwrap_or(false);
        assert!(!ok, "server still serving after stop");
    }
}
