//! RPC server: framed request/response over TCP, graceful shutdown.
//!
//! By default a thin binding onto the shared epoll reactor
//! ([`crate::net`]): connections are nonblocking state machines
//! ([`crate::net::conn::RpcProto`]) and handlers run on the bounded
//! worker pool, so thread count is O(workers + reactors) rather than
//! O(connections). The original thread-per-connection accept loop
//! survives behind `net.mode = "threaded"` (and as the automatic
//! fallback where epoll is unavailable).

use super::frame::{read_frame_into, write_framed};
use super::proto::{Request, Response};
use crate::net::conn::{rpc_reject_bytes, ProtocolFactory, RpcProto};
use crate::net::reactor::{ListenerId, Reactor};
use crate::net::track::ConnTracker;
use crate::net::{NetConfig, NetMetrics};
use crate::util::metrics::Registry;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Request handler: pure function from request to response. Handlers
/// run on worker (or connection) threads; anything shared must be Sync.
pub type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync>;

enum Mode {
    /// Thin binding onto an epoll reactor; `owned` reactors (built by
    /// the standalone constructor) are stopped with the server.
    Reactor {
        stack: Arc<Reactor>,
        listener: ListenerId,
        owned: bool,
    },
    /// Legacy thread-per-connection accept loop.
    Threaded {
        shutdown: Arc<AtomicBool>,
        accept_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
        conns: Arc<ConnTracker>,
    },
}

pub struct RpcServer {
    addr: SocketAddr,
    requests_served: Arc<AtomicU64>,
    mode: Mode,
    stopped: AtomicBool,
}

impl RpcServer {
    /// Bind and start serving `handler` on `addr` (use port 0 for an
    /// ephemeral port; read it back from [`RpcServer::addr`]). Runs on
    /// a private single-thread reactor (default [`NetConfig`]); falls
    /// back to the threaded accept loop where epoll is unavailable.
    pub fn start(addr: &str, handler: Handler) -> anyhow::Result<Arc<Self>> {
        let cfg = NetConfig::default();
        match Reactor::start(&cfg, NetMetrics::register(&Registry::new())) {
            Ok(stack) => Self::start_on(addr, handler, &stack, true),
            Err(e) => {
                crate::log_warn!("epoll reactor unavailable ({e}); using threaded listener");
                Self::start_threaded(addr, handler, &cfg)
            }
        }
    }

    /// Bind onto a shared reactor (the assembled server's I/O plane).
    /// `stop()` closes this listener only; the reactor outlives it.
    pub fn start_shared(
        addr: &str,
        handler: Handler,
        stack: &Arc<Reactor>,
    ) -> anyhow::Result<Arc<Self>> {
        Self::start_on(addr, handler, stack, false)
    }

    fn start_on(
        addr: &str,
        handler: Handler,
        stack: &Arc<Reactor>,
        owned: bool,
    ) -> anyhow::Result<Arc<Self>> {
        let listener = TcpListener::bind(addr)?;
        let requests_served = Arc::new(AtomicU64::new(0));
        let (make_handler, make_served) = (Arc::clone(&handler), Arc::clone(&requests_served));
        let factory = ProtocolFactory {
            label: "rpc",
            make: Box::new(move || {
                Box::new(RpcProto::new(Arc::clone(&make_handler), Arc::clone(&make_served)))
            }),
            reject: rpc_reject_bytes(),
        };
        let (listener, local) = stack.add_listener(listener, factory)?;
        crate::log_info!("rpc server listening on {local} (reactor)");
        Ok(Arc::new(RpcServer {
            addr: local,
            requests_served,
            mode: Mode::Reactor { stack: Arc::clone(stack), listener, owned },
            stopped: AtomicBool::new(false),
        }))
    }

    /// Legacy thread-per-connection listener (`net.mode = "threaded"`
    /// and the non-epoll fallback). `cfg` supplies the idle/read
    /// timeout and the `max_connections` gate.
    pub fn start_threaded(
        addr: &str,
        handler: Handler,
        cfg: &NetConfig,
    ) -> anyhow::Result<Arc<Self>> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let requests_served = Arc::new(AtomicU64::new(0));
        let conns = Arc::new(ConnTracker::new());

        let accept_shutdown = Arc::clone(&shutdown);
        let accept_counter = Arc::clone(&requests_served);
        let accept_conns = Arc::clone(&conns);
        let idle_timeout = cfg.idle_timeout;
        let max_connections = cfg.max_connections;
        let accept_thread = std::thread::Builder::new()
            .name(format!("rpc-accept-{}", local.port()))
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    match stream {
                        Ok(mut stream) => {
                            if max_connections > 0 && accept_conns.len() >= max_connections {
                                let _ = stream.write_all(&rpc_reject_bytes());
                                continue;
                            }
                            let handler = Arc::clone(&handler);
                            let counter = Arc::clone(&accept_counter);
                            let sd = Arc::clone(&accept_shutdown);
                            // Track before spawn so stop() can shut the
                            // socket down and join the thread instead of
                            // stranding it (detached-spawn bug).
                            let id = accept_conns.register(&stream);
                            let tracker = Arc::clone(&accept_conns);
                            let spawned = std::thread::Builder::new()
                                .name("rpc-conn".to_string())
                                .spawn(move || {
                                    Self::serve_connection(stream, handler, counter, sd, idle_timeout);
                                    if let Some(id) = id {
                                        tracker.deregister(id);
                                    }
                                });
                            if let (Some(id), Ok(handle)) = (id, spawned) {
                                accept_conns.attach(id, handle);
                            }
                        }
                        Err(e) => {
                            crate::log_warn!("accept error: {e}");
                        }
                    }
                }
            })?;

        crate::log_info!("rpc server listening on {local} (threaded)");
        Ok(Arc::new(RpcServer {
            addr: local,
            requests_served,
            mode: Mode::Threaded {
                shutdown,
                accept_thread: Mutex::new(Some(accept_thread)),
                conns,
            },
            stopped: AtomicBool::new(false),
        }))
    }

    fn serve_connection(
        mut stream: TcpStream,
        handler: Handler,
        counter: Arc<AtomicU64>,
        shutdown: Arc<AtomicBool>,
        idle_timeout: std::time::Duration,
    ) {
        let _ = stream.set_nodelay(true);
        // Idle connections wake from `read` every idle_timeout: they
        // either observe shutdown or are dropped, so `stop()` never
        // strands a thread blocked on a silent keep-alive peer.
        let _ = stream.set_read_timeout(Some(idle_timeout));
        // Per-connection scratch: frame payloads land in `payload` and
        // responses serialize into `encoded` — both reuse their
        // capacity across every request on this connection.
        let mut payload = Vec::new();
        let mut encoded = Vec::new();
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            match read_frame_into(&mut stream, &mut payload) {
                Ok(true) => {}
                Ok(false) => return, // client hung up
                Err(e) => {
                    crate::log_debug!("connection read error: {e}");
                    return;
                }
            }
            let response = match Request::decode(&payload) {
                Ok(req) => handler(req),
                Err(e) => Response::Error {
                    kind: crate::base::error::ErrorKind::InvalidArgument,
                    message: format!("bad request: {e}"),
                },
            };
            counter.fetch_add(1, Ordering::Relaxed);
            // Header bytes are reserved inside the scratch buffer, so
            // the reply is ONE write syscall; once the bytes are in
            // `encoded`, sole-owner output tensors go back to the pool.
            response.encode_framed_into(&mut encoded);
            response.recycle_buffers();
            if let Err(e) = write_framed(&mut stream, &mut encoded) {
                crate::log_debug!("connection write error: {e}");
                return;
            }
        }
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn requests_served(&self) -> u64 {
        self.requests_served.load(Ordering::Relaxed)
    }

    /// Stop accepting and release every connection. On the reactor
    /// path the listener closes and its connections are closed (idle
    /// ones now, in-flight ones after their reply flushes); a
    /// standalone server also stops its private reactor, which joins
    /// all threads. On the threaded path live connection sockets are
    /// shut down and their threads joined.
    pub fn stop(&self) {
        if self.stopped.swap(true, Ordering::SeqCst) {
            return;
        }
        match &self.mode {
            Mode::Reactor { stack, listener, owned } => {
                stack.close_listener(*listener);
                if *owned {
                    stack.stop();
                }
            }
            Mode::Threaded { shutdown, accept_thread, conns } => {
                shutdown.store(true, Ordering::SeqCst);
                // Poke the accept loop awake.
                let _ = TcpStream::connect(self.addr);
                if let Some(t) = accept_thread.lock().unwrap().take() {
                    let _ = t.join();
                }
                conns.stop_all();
            }
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::client::RpcClient;
    use crate::rpc::frame::{read_frame, write_frame};

    fn echo_handler() -> Handler {
        Arc::new(|req| match req {
            Request::Ping => Response::Pong,
            Request::Status => Response::Status { text: "ok".into() },
            _ => Response::Error {
                kind: crate::base::error::ErrorKind::Internal,
                message: "unsupported".into(),
            },
        })
    }

    fn echo_server() -> Arc<RpcServer> {
        RpcServer::start("127.0.0.1:0", echo_handler()).unwrap()
    }

    #[test]
    fn ping_pong() {
        let server = echo_server();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
        assert_eq!(
            client.call(&Request::Status).unwrap(),
            Response::Status { text: "ok".into() }
        );
        assert_eq!(server.requests_served(), 2);
    }

    #[test]
    fn concurrent_clients() {
        let server = echo_server();
        let addr = server.addr().to_string();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = RpcClient::connect(&addr).unwrap();
                    for _ in 0..50 {
                        assert_eq!(c.call(&Request::Ping).unwrap(), Response::Pong);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.requests_served(), 400);
    }

    #[test]
    fn malformed_request_gets_error_response() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        write_frame(&mut stream, &[42, 42, 42]).unwrap();
        let resp = Response::decode(&read_frame(&mut stream).unwrap().unwrap()).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
    }

    #[test]
    fn stop_then_connect_fails_eventually() {
        let server = echo_server();
        let addr = server.addr();
        server.stop();
        // The listener socket is closed after stop; new connections
        // must fail (immediately or after the OS backlog drains).
        std::thread::sleep(std::time::Duration::from_millis(50));
        let ok = TcpStream::connect(addr)
            .map(|mut s| {
                write_frame(&mut s, &Request::Ping.encode()).ok();
                read_frame(&mut s).ok().flatten().is_some()
            })
            .unwrap_or(false);
        assert!(!ok, "server still serving after stop");
    }

    #[test]
    fn threaded_mode_still_serves_and_stops_promptly() {
        let server =
            RpcServer::start_threaded("127.0.0.1:0", echo_handler(), &NetConfig::default())
                .unwrap();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
        // An idle keep-alive connection is open; stop() must still
        // return promptly (socket shutdown + join), not wait out the
        // 60s read timeout.
        let t0 = std::time::Instant::now();
        server.stop();
        assert!(t0.elapsed() < std::time::Duration::from_secs(10));
    }
}
