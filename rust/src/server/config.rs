//! Model-server configuration (the analogue of TF-Serving's
//! `ModelServerConfig` / `model_config_list` proto, as JSON).
//!
//! ```json
//! {
//!   "port": 8500,
//!   "http_addr": "0.0.0.0:8501",
//!   "artifacts_root": "artifacts",
//!   "poll_interval_ms": 500,
//!   "version_policy": "availability_preserving",
//!   "load_threads": 2,
//!   "ram_capacity_bytes": 0,
//!   "batching": {
//!     "enabled": true,
//!     "num_batch_threads": 2,
//!     "max_batch_size": 16,
//!     "batch_timeout_micros": 2000,
//!     "max_enqueued_batches": 64,
//!     "pool_shards": 0,
//!     "models": [
//!       {"name": "mlp_classifier", "max_batch_size": 64,
//!        "batch_timeout_micros": 500, "dedicated_threads": 2}
//!     ]
//!   },
//!   "models": [
//!     {"name": "mlp_classifier", "platform": "hlo", "serve_latest": 1},
//!     {"name": "toy_table", "platform": "table", "serve_latest": 1}
//!   ]
//! }
//! ```

use crate::base::error::ErrorKind;
use crate::lifecycle::source::ServingPolicy;
use crate::net::{NetConfig, NetMode};
use crate::serving::{AdmissionConfig, BatchingConfig, BatchingOverride};
use crate::util::config::Conf;
use anyhow::{bail, Result};
use std::path::PathBuf;
use std::time::Duration;

/// One served model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// "hlo" (the TensorFlow analogue) or "table" (BananaFlow).
    pub platform: String,
    /// Base path holding numeric version subdirectories. Defaults to
    /// `<artifacts_root>/<name>`.
    pub base_path: PathBuf,
    pub policy: ServingPolicy,
}

/// Full server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub port: u16,
    /// Listen address for the HTTP/REST gateway ("0.0.0.0:8501";
    /// ":0" ports bind ephemerally). `None` = RPC only.
    pub http_addr: Option<String>,
    pub artifacts_root: PathBuf,
    /// `None` = manual polling (tests).
    pub poll_interval: Option<Duration>,
    /// true = availability-preserving transitions; false = resource-.
    pub availability_preserving: bool,
    pub load_threads: usize,
    /// 0 = unlimited.
    pub ram_capacity_bytes: u64,
    /// Cross-request batching knobs (one `BatchingSession` per loaded
    /// servable version; see `serving::SessionRegistry`).
    pub batching: BatchingConfig,
    /// Bounded-in-flight admission control / load shedding (both caps
    /// default to 0 = unlimited, so shedding is strictly opt-in).
    pub admission: AdmissionConfig,
    /// Times the manager retries a version whose load fails before
    /// parking it in `Error` (0 = never retry, the conservative
    /// default; the previous version keeps serving either way).
    pub load_retries: u32,
    /// Backoff before the first load retry; doubles per attempt.
    pub load_retry_backoff: Duration,
    /// I/O plane knobs (reactor/worker threads, connection limits,
    /// idle sweeping) shared by both listeners.
    pub net: NetConfig,
    /// Durable version-label store path. When set, `SetVersionLabel`/
    /// `DeleteVersionLabel` write through to a transactional WAL+
    /// snapshot store here and persisted labels re-attach as their
    /// versions come back up after a restart. `None` = in-memory only.
    pub label_store_path: Option<PathBuf>,
    /// Fleet fault-injection tag: when set, every RPC this server
    /// handles consults the `rpc:{tag}` fault point, so chaos tests can
    /// fail or slow ONE replica (the registry is process-global; the
    /// tag scopes it). `None` = no per-replica seam.
    pub fault_tag: Option<String>,
    /// Rotation interval for windowed metrics (`*.window` series:
    /// per-version health error rates / latency p99, recent queue
    /// delay). A read covers 1–2 intervals, so this is the reaction
    /// half-life of health gates and SLO autoscaling. Must be > 0.
    pub metrics_window_ms: u64,
    pub models: Vec<ModelConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 0,
            http_addr: None,
            artifacts_root: crate::runtime::artifacts::default_artifacts_root(),
            poll_interval: Some(Duration::from_millis(500)),
            availability_preserving: true,
            load_threads: 2,
            ram_capacity_bytes: 0,
            batching: BatchingConfig::default(),
            admission: AdmissionConfig::default(),
            load_retries: 0,
            load_retry_backoff: Duration::from_millis(100),
            net: NetConfig::default(),
            label_store_path: None,
            fault_tag: None,
            metrics_window_ms: 1_000,
            models: Vec::new(),
        }
    }
}

impl ServerConfig {
    /// Parse from a JSON config document.
    pub fn from_conf(conf: &Conf) -> Result<ServerConfig> {
        conf.allow_keys(&[
            "port",
            "http_addr",
            "artifacts_root",
            "poll_interval_ms",
            "version_policy",
            "load_threads",
            "ram_capacity_bytes",
            "batching",
            "admission",
            "load_retries",
            "load_retry_backoff_ms",
            "net",
            "label_store_path",
            "fault_tag",
            "metrics_window_ms",
            "models",
        ])?;
        let artifacts_root = PathBuf::from(conf.str_or(
            "artifacts_root",
            crate::runtime::artifacts::default_artifacts_root()
                .to_str()
                .unwrap_or("artifacts"),
        ));
        let policy_name = conf.str_or("version_policy", "availability_preserving");
        let availability_preserving = match policy_name {
            "availability_preserving" => true,
            "resource_preserving" => false,
            other => bail!("unknown version_policy '{other}'"),
        };
        let poll_ms = conf.u64_or("poll_interval_ms", 500);
        let mut models = Vec::new();
        for m in conf.list("models")? {
            let name = m.str("name")?.to_string();
            let platform = m.str_or("platform", "hlo").to_string();
            if !["hlo", "table"].contains(&platform.as_str()) {
                bail!("model '{name}': unknown platform '{platform}'");
            }
            let base_path = m
                .root()
                .get("base_path")
                .and_then(|v| v.as_str())
                .map(PathBuf::from)
                .unwrap_or_else(|| artifacts_root.join(&name));
            let policy = if let Some(versions) = m.root().get("serve_versions") {
                let vs = versions
                    .as_arr()
                    .and_then(|a| {
                        a.iter().map(|v| v.as_u64()).collect::<Option<Vec<u64>>>()
                    })
                    .ok_or_else(|| anyhow::anyhow!("model '{name}': bad serve_versions"))?;
                ServingPolicy::Specific(vs)
            } else {
                ServingPolicy::Latest(m.u64_or("serve_latest", 1) as usize)
            };
            models.push(ModelConfig { name, platform, base_path, policy });
        }
        if models.is_empty() {
            bail!("config declares no models");
        }
        let batching = Self::batching_from_conf(conf)?;
        let admission = Self::admission_from_conf(conf)?;
        let net = Self::net_from_conf(conf)?;
        let load_retries = conf.u64_or("load_retries", 0) as u32;
        let load_retry_backoff_ms = conf.u64_or("load_retry_backoff_ms", 100);
        // Zero backoff with retries on would hammer a failing artifact
        // in a hot loop — a config typo, caught at parse time.
        if load_retries > 0 && load_retry_backoff_ms == 0 {
            return Err(ErrorKind::InvalidArgument.err(
                "load_retry_backoff_ms must be positive when load_retries is set",
            ));
        }
        // Empty strings for these would silently disable the feature
        // (or arm a fault point named "rpc:") — config typos.
        let label_store_path = conf
            .root()
            .get("label_store_path")
            .and_then(|v| v.as_str())
            .map(str::to_string);
        if label_store_path.as_deref() == Some("") {
            return Err(
                ErrorKind::InvalidArgument.err("label_store_path must not be empty")
            );
        }
        let fault_tag = conf
            .root()
            .get("fault_tag")
            .and_then(|v| v.as_str())
            .map(str::to_string);
        if fault_tag.as_deref() == Some("") {
            return Err(ErrorKind::InvalidArgument.err("fault_tag must not be empty"));
        }
        // A zero window would divide every rotation by it; reject the
        // typo at parse time like the other duration knobs.
        let metrics_window_ms = conf.u64_or("metrics_window_ms", 1_000);
        if metrics_window_ms == 0 {
            return Err(ErrorKind::InvalidArgument.err("metrics_window_ms must be positive"));
        }
        Ok(ServerConfig {
            port: conf.u64_or("port", 0) as u16,
            http_addr: conf
                .root()
                .get("http_addr")
                .and_then(|v| v.as_str())
                .map(str::to_string),
            artifacts_root,
            poll_interval: if poll_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(poll_ms))
            },
            availability_preserving,
            load_threads: conf.u64_or("load_threads", 2) as usize,
            ram_capacity_bytes: conf.u64_or("ram_capacity_bytes", 0),
            batching,
            admission,
            load_retries,
            load_retry_backoff: Duration::from_millis(load_retry_backoff_ms),
            net,
            label_store_path: label_store_path.map(PathBuf::from),
            fault_tag,
            metrics_window_ms,
            models,
        })
    }

    /// Parse the `"net"` object (all keys optional; absent = reactor
    /// mode with defaults).
    fn net_from_conf(conf: &Conf) -> Result<NetConfig> {
        let defaults = NetConfig::default();
        if let Some(obj) = conf.root().get("net") {
            Conf::from_json(obj.clone(), "net").allow_keys(&[
                "mode",
                "reactor_threads",
                "worker_threads",
                "max_connections",
                "idle_timeout_ms",
            ])?;
        }
        let mode = match conf.str_or("net.mode", "reactor") {
            "reactor" => NetMode::Reactor,
            "threaded" => NetMode::Threaded,
            other => bail!("net.mode: unknown mode '{other}' (reactor | threaded)"),
        };
        let net = NetConfig {
            mode,
            reactor_threads: conf
                .u64_or("net.reactor_threads", defaults.reactor_threads as u64)
                as usize,
            worker_threads: conf.u64_or("net.worker_threads", defaults.worker_threads as u64)
                as usize,
            max_connections: conf
                .u64_or("net.max_connections", defaults.max_connections as u64)
                as usize,
            idle_timeout: Duration::from_millis(
                conf.u64_or("net.idle_timeout_ms", defaults.idle_timeout.as_millis() as u64),
            ),
        };
        // Zero threads would deadlock every request; a zero idle
        // timeout would sweep connections as they arrive. Config
        // typos, caught at parse time (max_connections 0 = unlimited
        // stays valid).
        if net.reactor_threads == 0 || net.worker_threads == 0 {
            return Err(ErrorKind::InvalidArgument
                .err("net: reactor_threads and worker_threads must be positive"));
        }
        if net.idle_timeout.is_zero() {
            return Err(ErrorKind::InvalidArgument
                .err("net: idle_timeout_ms must be positive (raise it instead of disabling)"));
        }
        Ok(net)
    }

    /// Parse the `"admission"` object (all keys optional; absent =
    /// unlimited, i.e. no shedding).
    fn admission_from_conf(conf: &Conf) -> Result<AdmissionConfig> {
        let defaults = AdmissionConfig::default();
        if let Some(obj) = conf.root().get("admission") {
            Conf::from_json(obj.clone(), "admission").allow_keys(&[
                "max_inflight",
                "max_inflight_per_model",
                "retry_after_ms",
            ])?;
        }
        let admission = AdmissionConfig {
            max_inflight: conf
                .u64_or("admission.max_inflight", defaults.max_inflight as u64)
                as usize,
            max_inflight_per_model: conf.u64_or(
                "admission.max_inflight_per_model",
                defaults.max_inflight_per_model as u64,
            ) as usize,
            retry_after_ms: conf.u64_or("admission.retry_after_ms", defaults.retry_after_ms),
        };
        // A per-model cap above the global cap can never be reached —
        // a config typo, caught here rather than silently ignored.
        if admission.max_inflight > 0
            && admission.max_inflight_per_model > admission.max_inflight
        {
            return Err(ErrorKind::InvalidArgument.err(format!(
                "admission: max_inflight_per_model ({}) exceeds max_inflight ({})",
                admission.max_inflight_per_model, admission.max_inflight
            )));
        }
        Ok(admission)
    }

    /// Parse the `"batching"` object (all keys optional; absent object
    /// = defaults with batching enabled).
    fn batching_from_conf(conf: &Conf) -> Result<BatchingConfig> {
        let defaults = BatchingConfig::default();
        if let Some(obj) = conf.root().get("batching") {
            Conf::from_json(obj.clone(), "batching").allow_keys(&[
                "enabled",
                "num_batch_threads",
                "max_batch_size",
                "batch_timeout_micros",
                "max_enqueued_batches",
                "pool_shards",
                "models",
            ])?;
        }
        let mut per_model = std::collections::HashMap::new();
        if conf.root().get_path("batching.models").is_some() {
            for m in conf.list("batching.models")? {
                m.allow_keys(&[
                    "name",
                    "max_batch_size",
                    "batch_timeout_micros",
                    "max_enqueued_batches",
                    "dedicated_threads",
                ])?;
                let name = m.str("name")?.to_string();
                let get = |key: &str| m.root().get(key).and_then(|v| v.as_u64());
                per_model.insert(
                    name,
                    BatchingOverride {
                        max_batch_size: get("max_batch_size").map(|v| v as usize),
                        batch_timeout: get("batch_timeout_micros").map(Duration::from_micros),
                        max_enqueued_batches: get("max_enqueued_batches")
                            .map(|v| v as usize),
                        dedicated_threads: get("dedicated_threads").map(|v| v as usize),
                    },
                );
            }
        }
        // Zero-capacity knobs are config typos, caught here (parse
        // time) rather than as a panic when the first servable loads.
        // Kind: InvalidArgument — a config-shaped request problem.
        for (name, o) in &per_model {
            if o.max_batch_size == Some(0) || o.max_enqueued_batches == Some(0) {
                return Err(ErrorKind::InvalidArgument.err(format!(
                    "batching.models['{name}']: max_batch_size / max_enqueued_batches \
                     must be positive"
                )));
            }
            // dedicated_threads: 0 would mean "a private worker set of
            // nobody" — the lane would never drain. Omit the key to
            // use the shared pool.
            if o.dedicated_threads == Some(0) {
                return Err(ErrorKind::InvalidArgument.err(format!(
                    "batching.models['{name}']: dedicated_threads must be positive \
                     (omit the key to use the shared worker pool)"
                )));
            }
        }
        // Shard count is clamped (power of two in [1, MAX_SHARDS]), not
        // rejected: 0 = auto-size from the machine's parallelism.
        let pool_shards = conf.u64_or("batching.pool_shards", 0) as usize;
        let pool_shards = if pool_shards == 0 {
            0
        } else {
            crate::util::pool::clamp_shards(pool_shards)
        };
        let batching = BatchingConfig {
            enabled: conf.bool_or("batching.enabled", defaults.enabled),
            num_batch_threads: conf
                .u64_or("batching.num_batch_threads", defaults.num_batch_threads as u64)
                as usize,
            max_batch_size: conf
                .u64_or("batching.max_batch_size", defaults.max_batch_size as u64)
                as usize,
            batch_timeout: Duration::from_micros(conf.u64_or(
                "batching.batch_timeout_micros",
                defaults.batch_timeout.as_micros() as u64,
            )),
            max_enqueued_batches: conf.u64_or(
                "batching.max_enqueued_batches",
                defaults.max_enqueued_batches as u64,
            ) as usize,
            pool_shards,
            per_model,
        };
        if batching.max_batch_size == 0
            || batching.max_enqueued_batches == 0
            || batching.num_batch_threads == 0
        {
            return Err(ErrorKind::InvalidArgument.err(
                "batching: num_batch_threads, max_batch_size and max_enqueued_batches \
                 must be positive",
            ));
        }
        Ok(batching)
    }

    pub fn load(path: &std::path::Path) -> Result<ServerConfig> {
        Self::from_conf(&Conf::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "port": 8500,
      "http_addr": "0.0.0.0:8501",
      "artifacts_root": "/a",
      "poll_interval_ms": 100,
      "version_policy": "resource_preserving",
      "models": [
        {"name": "c", "platform": "hlo", "serve_latest": 2},
        {"name": "t", "platform": "table", "base_path": "/elsewhere/t"},
        {"name": "pinned", "serve_versions": [3, 5]}
      ]
    }"#;

    #[test]
    fn parse_full_config() {
        let cfg = ServerConfig::from_conf(&Conf::parse(SAMPLE, "t").unwrap()).unwrap();
        assert_eq!(cfg.port, 8500);
        assert_eq!(cfg.http_addr.as_deref(), Some("0.0.0.0:8501"));
        assert!(!cfg.availability_preserving);
        assert_eq!(cfg.poll_interval, Some(Duration::from_millis(100)));
        assert_eq!(cfg.models.len(), 3);
        assert_eq!(cfg.models[0].policy, ServingPolicy::Latest(2));
        assert_eq!(cfg.models[0].base_path, PathBuf::from("/a/c"));
        assert_eq!(cfg.models[1].base_path, PathBuf::from("/elsewhere/t"));
        assert_eq!(cfg.models[2].platform, "hlo"); // default
        assert_eq!(cfg.models[2].policy, ServingPolicy::Specific(vec![3, 5]));
    }

    #[test]
    fn rejects_bad_configs() {
        for (bad, needle) in [
            (r#"{"models": []}"#, "no models"),
            (r#"{"models": [{"name":"x","platform":"gpu"}]}"#, "platform"),
            (r#"{"version_policy":"yolo","models":[{"name":"x"}]}"#, "version_policy"),
            (r#"{"prot": 1, "models":[{"name":"x"}]}"#, "unknown key"),
        ] {
            let err = ServerConfig::from_conf(&Conf::parse(bad, "t").unwrap())
                .unwrap_err()
                .to_string();
            assert!(err.contains(needle), "{bad}: {err}");
        }
    }

    #[test]
    fn batching_defaults_and_overrides() {
        // No "batching" object: enabled with defaults.
        let cfg = ServerConfig::from_conf(
            &Conf::parse(r#"{"models":[{"name":"x"}]}"#, "t").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.batching, crate::serving::BatchingConfig::default());
        assert!(cfg.batching.enabled);

        // Full object with a per-model override.
        let cfg = ServerConfig::from_conf(
            &Conf::parse(
                r#"{
                  "batching": {
                    "enabled": true,
                    "num_batch_threads": 4,
                    "max_batch_size": 64,
                    "batch_timeout_micros": 500,
                    "max_enqueued_batches": 32,
                    "models": [{"name": "c", "max_batch_size": 8}]
                  },
                  "models": [{"name": "c"}]
                }"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.batching.num_batch_threads, 4);
        assert_eq!(cfg.batching.max_batch_size, 64);
        assert_eq!(cfg.batching.batch_timeout, Duration::from_micros(500));
        assert_eq!(cfg.batching.max_enqueued_batches, 32);
        assert_eq!(
            cfg.batching.per_model.get("c").unwrap().max_batch_size,
            Some(8)
        );
        assert_eq!(cfg.batching.per_model.get("c").unwrap().batch_timeout, None);

        // Zero-capacity knobs are rejected at parse time (they would
        // otherwise panic the scheduler at servable-load time).
        for bad in [
            r#"{"batching": {"max_batch_size": 0}, "models":[{"name":"x"}]}"#,
            r#"{"batching": {"num_batch_threads": 0}, "models":[{"name":"x"}]}"#,
            r#"{"batching": {"max_enqueued_batches": 0}, "models":[{"name":"x"}]}"#,
            r#"{"batching": {"models": [{"name":"x","max_batch_size":0}]},
                "models":[{"name":"x"}]}"#,
        ] {
            let err = ServerConfig::from_conf(&Conf::parse(bad, "t").unwrap())
                .unwrap_err()
                .to_string();
            assert!(err.contains("positive"), "{bad}: {err}");
        }

        // dedicated_threads parses per model; 0 is rejected at parse
        // time with an InvalidArgument kind (PR 4 validation style).
        let cfg = ServerConfig::from_conf(
            &Conf::parse(
                r#"{
                  "batching": {"models": [{"name": "vip", "dedicated_threads": 2}]},
                  "models": [{"name": "vip"}]
                }"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(
            cfg.batching.per_model.get("vip").unwrap().dedicated_threads,
            Some(2)
        );
        let err = ServerConfig::from_conf(
            &Conf::parse(
                r#"{
                  "batching": {"models": [{"name": "vip", "dedicated_threads": 0}]},
                  "models": [{"name": "vip"}]
                }"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap_err();
        assert_eq!(
            crate::base::error::ErrorKind::of(&err),
            crate::base::error::ErrorKind::InvalidArgument
        );
        assert!(err.to_string().contains("dedicated_threads"), "{err}");

        // pool_shards is clamped (power of two, capped), never an
        // error; 0/absent = auto.
        for (json, want) in [
            (r#"{"batching": {"pool_shards": 5}, "models":[{"name":"x"}]}"#, 8usize),
            (r#"{"batching": {"pool_shards": 100000}, "models":[{"name":"x"}]}"#,
             crate::util::pool::MAX_SHARDS),
            (r#"{"batching": {"pool_shards": 0}, "models":[{"name":"x"}]}"#, 0),
            (r#"{"models":[{"name":"x"}]}"#, 0),
        ] {
            let cfg =
                ServerConfig::from_conf(&Conf::parse(json, "t").unwrap()).unwrap();
            assert_eq!(cfg.batching.pool_shards, want, "{json}");
        }

        // Zero-capacity rejections carry the InvalidArgument kind too.
        let err = ServerConfig::from_conf(
            &Conf::parse(
                r#"{"batching": {"max_batch_size": 0}, "models":[{"name":"x"}]}"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap_err();
        assert_eq!(
            crate::base::error::ErrorKind::of(&err),
            crate::base::error::ErrorKind::InvalidArgument
        );

        // Disabled is parseable; unknown batching keys are typos.
        let cfg = ServerConfig::from_conf(
            &Conf::parse(
                r#"{"batching": {"enabled": false}, "models":[{"name":"x"}]}"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap();
        assert!(!cfg.batching.enabled);
        let err = ServerConfig::from_conf(
            &Conf::parse(
                r#"{"batching": {"max_batchsize": 4}, "models":[{"name":"x"}]}"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown key"), "{err}");
    }

    #[test]
    fn admission_and_load_retry_knobs() {
        // Absent: unlimited admission, no load retries.
        let cfg = ServerConfig::from_conf(
            &Conf::parse(r#"{"models":[{"name":"x"}]}"#, "t").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.admission, AdmissionConfig::default());
        assert_eq!(cfg.load_retries, 0);
        assert_eq!(cfg.load_retry_backoff, Duration::from_millis(100));
        assert_eq!(cfg.metrics_window_ms, 1_000);

        // Full parse.
        let cfg = ServerConfig::from_conf(
            &Conf::parse(
                r#"{
                  "admission": {
                    "max_inflight": 64,
                    "max_inflight_per_model": 16,
                    "retry_after_ms": 250
                  },
                  "load_retries": 3,
                  "load_retry_backoff_ms": 20,
                  "metrics_window_ms": 250,
                  "models": [{"name": "x"}]
                }"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.admission.max_inflight, 64);
        assert_eq!(cfg.admission.max_inflight_per_model, 16);
        assert_eq!(cfg.admission.retry_after_ms, 250);
        assert_eq!(cfg.load_retries, 3);
        assert_eq!(cfg.load_retry_backoff, Duration::from_millis(20));
        assert_eq!(cfg.metrics_window_ms, 250);

        // Config typos are parse-time InvalidArgument errors.
        for (bad, needle) in [
            (
                r#"{"admission": {"max_inflight": 4, "max_inflight_per_model": 8},
                    "models":[{"name":"x"}]}"#,
                "exceeds max_inflight",
            ),
            (
                r#"{"load_retries": 2, "load_retry_backoff_ms": 0,
                    "models":[{"name":"x"}]}"#,
                "load_retry_backoff_ms",
            ),
            (
                r#"{"admission": {"max_in_flight": 4}, "models":[{"name":"x"}]}"#,
                "unknown key",
            ),
            (
                r#"{"metrics_window_ms": 0, "models":[{"name":"x"}]}"#,
                "metrics_window_ms",
            ),
        ] {
            let err = ServerConfig::from_conf(&Conf::parse(bad, "t").unwrap()).unwrap_err();
            assert!(err.to_string().contains(needle), "{bad}: {err}");
            if !needle.contains("unknown key") {
                assert_eq!(ErrorKind::of(&err), ErrorKind::InvalidArgument, "{bad}");
            }
        }
    }

    #[test]
    fn net_knobs_parse_and_validate() {
        // Absent: reactor mode with defaults.
        let cfg = ServerConfig::from_conf(
            &Conf::parse(r#"{"models":[{"name":"x"}]}"#, "t").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.net, NetConfig::default());
        assert_eq!(cfg.net.mode, NetMode::Reactor);

        // Full parse.
        let cfg = ServerConfig::from_conf(
            &Conf::parse(
                r#"{
                  "net": {
                    "mode": "threaded",
                    "reactor_threads": 2,
                    "worker_threads": 8,
                    "max_connections": 1024,
                    "idle_timeout_ms": 5000
                  },
                  "models": [{"name": "x"}]
                }"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.net.mode, NetMode::Threaded);
        assert_eq!(cfg.net.reactor_threads, 2);
        assert_eq!(cfg.net.worker_threads, 8);
        assert_eq!(cfg.net.max_connections, 1024);
        assert_eq!(cfg.net.idle_timeout, Duration::from_millis(5000));

        // Config typos are parse-time errors (InvalidArgument for the
        // range violations, PR4 style).
        for (bad, needle) in [
            (r#"{"net": {"mode": "uring"}, "models":[{"name":"x"}]}"#, "unknown mode"),
            (r#"{"net": {"reactor_threads": 0}, "models":[{"name":"x"}]}"#, "positive"),
            (r#"{"net": {"worker_threads": 0}, "models":[{"name":"x"}]}"#, "positive"),
            (r#"{"net": {"idle_timeout_ms": 0}, "models":[{"name":"x"}]}"#, "idle_timeout_ms"),
            (r#"{"net": {"workerthreads": 4}, "models":[{"name":"x"}]}"#, "unknown key"),
        ] {
            let err = ServerConfig::from_conf(&Conf::parse(bad, "t").unwrap()).unwrap_err();
            assert!(err.to_string().contains(needle), "{bad}: {err}");
        }
        let err = ServerConfig::from_conf(
            &Conf::parse(
                r#"{"net": {"worker_threads": 0}, "models":[{"name":"x"}]}"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap_err();
        assert_eq!(ErrorKind::of(&err), ErrorKind::InvalidArgument);

        // max_connections 0 = unlimited stays valid.
        let cfg = ServerConfig::from_conf(
            &Conf::parse(
                r#"{"net": {"max_connections": 0}, "models":[{"name":"x"}]}"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.net.max_connections, 0);
    }

    #[test]
    fn fleet_knobs_parse_and_validate() {
        // Absent: both off.
        let cfg = ServerConfig::from_conf(
            &Conf::parse(r#"{"models":[{"name":"x"}]}"#, "t").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.label_store_path, None);
        assert_eq!(cfg.fault_tag, None);

        let cfg = ServerConfig::from_conf(
            &Conf::parse(
                r#"{"label_store_path": "/var/lib/ts/labels",
                    "fault_tag": "job-0/1",
                    "models":[{"name":"x"}]}"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.label_store_path, Some(PathBuf::from("/var/lib/ts/labels")));
        assert_eq!(cfg.fault_tag.as_deref(), Some("job-0/1"));

        // Empty strings are typos, rejected at parse time.
        for bad in [
            r#"{"label_store_path": "", "models":[{"name":"x"}]}"#,
            r#"{"fault_tag": "", "models":[{"name":"x"}]}"#,
        ] {
            let err =
                ServerConfig::from_conf(&Conf::parse(bad, "t").unwrap()).unwrap_err();
            assert_eq!(ErrorKind::of(&err), ErrorKind::InvalidArgument, "{bad}");
        }
    }

    #[test]
    fn zero_poll_means_manual() {
        let cfg = ServerConfig::from_conf(
            &Conf::parse(
                r#"{"poll_interval_ms": 0, "models":[{"name":"x"}]}"#,
                "t",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.poll_interval, None);
        assert_eq!(cfg.http_addr, None); // RPC-only unless configured
    }
}
