//! The canonical server binary's building blocks (paper §3):
//! "a 'vanilla' set-up consisting of a file-system-monitoring Source, a
//! TensorFlow Source Adapter and a Manager", packaged so "most users do
//! not need to fuss with our lower-level library offering".
//!
//! [`config`] parses the model-server config; [`builder`] assembles
//! Source → Router → Adapters → AspiredVersionsManager behind the RPC
//! front end, with metrics and request logging.

pub mod builder;
pub mod config;
