//! [`ModelServer`]: the assembled canonical server.
//!
//! Wiring (paper Figure 1 made concrete):
//!
//! ```text
//! FileSystemSource ──► SourceRouter (by platform)
//!                        ├─ port 0 ─► HloSourceAdapter ──► AVM
//!                        └─ port 1 ─► TableSourceAdapter ─► AVM
//! RPC front end ──► Predict/Classify/Regress/MultiInference/Lookup
//!              │     over AVM handles (ModelSpec: version or label,
//!              │     signatures validated) + GetModelMetadata
//!              └──► admin: SetAspired (RPC source), SetVersionLabel,
//!                   DeleteVersionLabel, ModelStatus, Status
//! HTTP gateway ──► the same ServerCore::handle over JSON
//!                  (http::router), when `http_addr` is configured
//! ```
//!
//! Version labels are garbage-collected on the unload path: an event-
//! bus subscription drops any label whose version leaves serving, so
//! labels never dangle on unloaded versions.

use super::config::ServerConfig;
use crate::base::aspired::{AspiredVersionsCallback, Source};
use crate::base::error::ErrorKind;
use crate::http::server::HttpServer;
use crate::inference::classify::{classify_with_opts, ClassifyRequest};
use crate::inference::example::Feature;
use crate::inference::logger::{digest_f32s, RequestLogger};
use crate::inference::multi::{multi_inference_with_opts, MultiInferenceRequest};
use crate::inference::predict::{predict_with_opts, LabeledSource, PredictRequest};
use crate::inference::regress::{regress_with_opts, RegressRequest};
use crate::inference::table::{table_source_adapter, TableServable};
use crate::inference::ModelSpec;
use crate::lifecycle::basic_manager::{ManagerOptions, VersionRequest};
use crate::lifecycle::labels::LabelResolver;
use crate::lifecycle::manager::{AspiredVersionsManager, AvmOptions};
use crate::lifecycle::policy::{
    AvailabilityPreservingPolicy, ResourcePreservingPolicy, VersionPolicy,
};
use crate::lifecycle::source::{FileSystemSource, ServingPolicy, WatchedServable};
use crate::lifecycle::source_router::SourceRouter;
use crate::net::{NetMetrics, NetMode, Reactor};
use crate::rpc::proto::{Request, Response, VersionMetadata};
use crate::rpc::server::RpcServer;
use crate::runtime::hlo_servable::{hlo_source_adapter, HloServable};
use crate::runtime::pjrt::XlaRuntime;
use crate::serving::{AdmissionControl, RunOptions, SessionRegistry};
use crate::tfs2::store::Store;
use crate::util::json::Json;
use crate::util::metrics::Registry;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Handler-visible server state (shared with the RPC closure).
pub struct ServerCore {
    pub config: ServerConfig,
    avm: Arc<AspiredVersionsManager>,
    source: Arc<FileSystemSource>,
    /// Version labels ("canary"/"stable" → version), consulted on
    /// every labeled lookup.
    pub labels: Arc<LabelResolver>,
    /// Per-servable batching sessions (the cross-request merge layer
    /// both wire planes execute through).
    pub sessions: Arc<SessionRegistry>,
    /// Bounded-in-flight admission control + the drain switch; every
    /// data-plane request holds one of its permits while executing.
    pub admission: Arc<AdmissionControl>,
    pub registry: Arc<Registry>,
    pub logger: Arc<RequestLogger>,
    /// Durable label store (TFS²): when `label_store_path` is set,
    /// label mutations write through here and Ready events replay the
    /// persisted mappings, so canary/stable labels survive restarts.
    label_store: Option<Arc<Store>>,
    /// Per-model rollout status pushed by the fleet control plane
    /// (`SetRolloutStatus`), surfaced in `GET /v1/models` so operators
    /// see canary progress and auto-rollback reasons on any replica.
    rollout_status: std::sync::Mutex<HashMap<String, String>>,
}

/// The running canonical server.
pub struct ModelServer {
    core: Arc<ServerCore>,
    rpc: Arc<RpcServer>,
    /// The REST gateway, when `http_addr` is configured.
    http: Option<Arc<HttpServer>>,
    /// The shared epoll reactor both listeners bind onto; `None` in
    /// threaded mode (or after the epoll fallback fired).
    net_stack: Option<Arc<Reactor>>,
}

impl ModelServer {
    /// Build and start everything; returns once the RPC server is
    /// listening (models may still be loading — see
    /// [`ModelServer::wait_until_ready`]).
    pub fn start(config: ServerConfig) -> Result<Arc<Self>> {
        // Chaos knob: arm fault points from TENSORSERVE_FAULTS before
        // anything loads, so even the first load can be made to fail.
        match crate::util::fault::arm_from_env()? {
            0 => {}
            n => crate::log_info!("fault injection: {n} point(s) armed from env"),
        }
        // Buffer-pool sharding must be requested before the global
        // pools' first touch; afterwards the shard count is fixed for
        // the process (log, don't fail — any count works).
        if config.batching.pool_shards > 0
            && !crate::util::pool::configure_global_shards(config.batching.pool_shards)
        {
            crate::log_info!(
                "batching.pool_shards={} requested after the global buffer pools \
                 were built; keeping the existing shard count",
                config.batching.pool_shards
            );
        }
        // Manager.
        let policy: Arc<dyn VersionPolicy> = if config.availability_preserving {
            Arc::new(AvailabilityPreservingPolicy)
        } else {
            Arc::new(ResourcePreservingPolicy)
        };
        let avm = AspiredVersionsManager::new(
            policy,
            AvmOptions {
                manager: ManagerOptions {
                    load_threads: config.load_threads,
                    ram_capacity_bytes: if config.ram_capacity_bytes == 0 {
                        None
                    } else {
                        Some(config.ram_capacity_bytes)
                    },
                    name: "server".into(),
                    ..Default::default()
                },
                reconcile_interval: Some(Duration::from_millis(20)),
                num_load_retries: config.load_retries,
                load_retry_backoff: config.load_retry_backoff,
            },
        );

        // Platform router + adapters (Figure 1). Models added at
        // runtime (TFS² SetAspired) aren't in the config map: sniff the
        // platform from the artifact layout (table.json ⇒ BananaFlow).
        // This runs on the lifecycle path, never per-request.
        let platform_of: HashMap<String, usize> = config
            .models
            .iter()
            .map(|m| (m.name.clone(), usize::from(m.platform == "table")))
            .collect();
        let sniff_root = config.artifacts_root.clone();
        let router = SourceRouter::<PathBuf>::new(2, move |name| {
            if let Some(&port) = platform_of.get(name) {
                return port;
            }
            let base = sniff_root.join(name);
            let is_table = crate::lifecycle::source::scan_versions(&base)
                .last()
                .map(|v| base.join(v.to_string()).join("table.json").exists())
                .unwrap_or(false);
            usize::from(is_table)
        });
        let runtime = XlaRuntime::shared()?;
        let hlo_adapter = hlo_source_adapter(runtime);
        let table_adapter = table_source_adapter();
        hlo_adapter.connect(Arc::clone(&avm) as Arc<dyn AspiredVersionsCallback<_>>);
        table_adapter.connect(Arc::clone(&avm) as Arc<dyn AspiredVersionsCallback<_>>);
        router.connect_port(0, hlo_adapter);
        router.connect_port(1, table_adapter);

        // File-system source.
        let watched = config
            .models
            .iter()
            .map(|m| WatchedServable {
                name: m.name.clone(),
                base_path: m.base_path.clone(),
                policy: m.policy.clone(),
            })
            .collect();
        let mut source = FileSystemSource::new(watched, config.poll_interval);
        source.set_aspired_versions_callback(router);

        // Cross-request batching: one session per loaded (model,
        // version), kept in sync with the lifecycle via the event bus
        // (sessions open on Ready, drain on the unload path). Both the
        // RPC and HTTP planes execute through this registry, so their
        // concurrent requests merge into shared device batches.
        // The registry's windowed series (health.*, *.window) rotate on
        // `metrics_window_ms`, so the fleet Synchronizer scrapes recent
        // error-rate/p99 instead of cumulative-since-boot distributions.
        let registry = Registry::with_window(
            crate::util::clock::RealClock::shared(),
            Duration::from_millis(config.metrics_window_ms),
        );
        let sessions = SessionRegistry::new(config.batching.clone(), Arc::clone(&registry));
        sessions.attach(avm.basic());
        let admission = AdmissionControl::new(config.admission.clone(), &registry);

        // Durable labels: open the transactional store up front so a
        // corrupt path fails the boot, not the first SetVersionLabel.
        let label_store = match &config.label_store_path {
            Some(path) => Some(Store::open(path, 0)?),
            None => None,
        };

        let core = Arc::new(ServerCore {
            config: config.clone(),
            avm,
            source,
            labels: Arc::new(LabelResolver::new()),
            sessions,
            admission,
            registry,
            logger: Arc::new(RequestLogger::new(0.1, 4096, 42)),
            label_store,
            rollout_status: std::sync::Mutex::new(HashMap::new()),
        });

        // Label GC: drop labels whose version leaves serving, so a
        // labeled lookup after an unload reports "no version labeled"
        // instead of dangling on a version the serving map no longer
        // holds (closes the set-time-only race in `SetVersionLabel`).
        // GC is in-memory only: a persisted label deliberately stays
        // in the store so it replays if its version comes back.
        let gc_labels = Arc::clone(&core.labels);
        core.avm.basic().bus().subscribe(Arc::new(move |ev| {
            use crate::lifecycle::harness::State;
            if matches!(ev.state, State::Unloading | State::Disabled | State::Error(_)) {
                for label in gc_labels.remove_version(&ev.id.name, ev.id.version) {
                    crate::log_info!(
                        "label GC: dropped '{label}' from {} (version {} left serving)",
                        ev.id.name,
                        ev.id.version
                    );
                }
            }
        }));

        // Label replay: persisted labels re-attach when their version
        // reaches Ready, so canary/stable mappings survive a restart
        // without waiting for an operator to re-issue them.
        if let Some(store) = &core.label_store {
            let replay_store = Arc::clone(store);
            let replay_labels = Arc::clone(&core.labels);
            core.avm.basic().bus().subscribe(Arc::new(move |ev| {
                use crate::lifecycle::harness::State;
                if !matches!(ev.state, State::Ready) {
                    return;
                }
                for (key, value) in
                    replay_store.scan_prefix(&format!("label/{}/", ev.id.name))
                {
                    let Some(label) = key.rsplit('/').next() else { continue };
                    let Some(version) = value.as_u64() else { continue };
                    if version != ev.id.version {
                        continue;
                    }
                    // The Ready event itself attests the version is
                    // serving; consulting the ready map here instead
                    // would race the map update the event describes.
                    if replay_labels.set(&ev.id.name, label, version, &[version]).is_ok() {
                        crate::log_info!(
                            "label replay: '{label}' -> {}:{version} restored from store",
                            ev.id.name
                        );
                    }
                }
            }));
        }

        // The I/O plane: one epoll reactor stack shared by both
        // listeners, so connection count never translates into thread
        // count. Threaded mode (config or epoll failure) falls back to
        // the legacy per-connection accept loops.
        let net_stack = match config.net.mode {
            NetMode::Reactor => {
                match Reactor::start(&config.net, NetMetrics::register(&core.registry)) {
                    Ok(stack) => Some(stack),
                    Err(e) => {
                        crate::log_warn!(
                            "net: reactor unavailable ({e:#}); \
                             falling back to threaded connection handling"
                        );
                        None
                    }
                }
            }
            NetMode::Threaded => None,
        };

        // RPC front end.
        let handler_core = Arc::clone(&core);
        let rpc_addr = format!("0.0.0.0:{}", config.port);
        let rpc_handler: crate::rpc::server::Handler =
            Arc::new(move |req| handler_core.handle(req));
        let rpc = match &net_stack {
            Some(stack) => RpcServer::start_shared(&rpc_addr, rpc_handler, stack)?,
            None => RpcServer::start_threaded(&rpc_addr, rpc_handler, &config.net)?,
        };

        // HTTP/REST gateway: same core, wire codec negotiated per
        // request; data-plane bodies stream through the sink factory's
        // incremental decoders on both transport paths.
        let http = match &core.config.http_addr {
            Some(addr) => {
                let gateway = crate::http::router::gateway(Arc::clone(&core));
                let sinks = crate::http::router::sink_factory(Arc::clone(&core));
                Some(match &net_stack {
                    Some(stack) => HttpServer::start_shared_with(addr, gateway, sinks, stack)?,
                    None => {
                        HttpServer::start_threaded_with(addr, gateway, Some(sinks), &config.net)?
                    }
                })
            }
            None => None,
        };
        Ok(Arc::new(ModelServer { core, rpc, http, net_stack }))
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.rpc.addr()
    }

    /// Bound address of the REST gateway, when one is configured.
    pub fn http_addr(&self) -> Option<std::net::SocketAddr> {
        self.http.as_ref().map(|h| h.addr())
    }

    pub fn core(&self) -> &Arc<ServerCore> {
        &self.core
    }

    pub fn avm(&self) -> &Arc<AspiredVersionsManager> {
        &self.core.avm
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.core.registry
    }

    /// Canary/rollback control (§2.1.1).
    pub fn set_serving_policy(&self, model: &str, policy: ServingPolicy) {
        self.core.set_serving_policy(model, policy);
    }

    /// Block until every configured model has at least one ready
    /// version (or timeout). Returns the ready map.
    pub fn wait_until_ready(&self, timeout: Duration) -> Result<HashMap<String, Vec<u64>>> {
        let deadline = Instant::now() + timeout;
        loop {
            let ready: HashMap<String, Vec<u64>> = self
                .core
                .config
                .models
                .iter()
                .map(|m| (m.name.clone(), self.core.avm.basic().ready_versions(&m.name)))
                .collect();
            if ready.values().all(|v| !v.is_empty()) {
                return Ok(ready);
            }
            if Instant::now() >= deadline {
                return Err(anyhow!("models not ready after {timeout:?}: {ready:?}"));
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Graceful drain, then teardown: new data-plane work is refused
    /// with a retryable `Unavailable` (pointing clients at another
    /// replica), already-admitted requests get a bounded window to
    /// finish, and only then do the listeners close.
    pub fn stop(&self) {
        self.core.admission.start_draining();
        if !self.core.admission.wait_idle(Duration::from_secs(5)) {
            crate::log_warn!(
                "drain window expired with {} request(s) still in flight",
                self.core.admission.inflight()
            );
        }
        self.rpc.stop();
        if let Some(http) = &self.http {
            http.stop();
        }
        // Listeners are gone and in-flight replies have drained through
        // the per-server stops; now tear down the shared reactor pool.
        if let Some(stack) = &self.net_stack {
            stack.stop();
        }
    }
}

impl ServerCore {
    pub fn avm(&self) -> &Arc<AspiredVersionsManager> {
        &self.avm
    }

    /// Canary/rollback control (§2.1.1): change the serving policy for
    /// one model and re-poll immediately. Models not yet watched (TFS²
    /// assigns them at runtime) are added, served from
    /// `<artifacts_root>/<model>`.
    pub fn set_serving_policy(&self, model: &str, policy: ServingPolicy) {
        if !self.source.is_watching(model) {
            self.source.watch(crate::lifecycle::source::WatchedServable {
                name: model.to_string(),
                base_path: self.config.artifacts_root.join(model),
                policy: policy.clone(),
            });
        }
        self.source.set_policy(model, policy);
        self.source.poll_once();
    }

    /// The RPC request handler (one call per request frame).
    pub fn handle(&self, req: Request) -> Response {
        let t0 = Instant::now();
        // Per-replica fault seam: a configured `fault_tag` exposes the
        // whole handler as fault point `rpc:{tag}`, so fleet tests can
        // slow or fail one replica in a process hosting many (the
        // plain `exec:{model}` point hits every replica at once).
        if let Some(tag) = &self.config.fault_tag {
            if let Err(e) = crate::util::fault::hit(&format!("rpc:{tag}")) {
                return Response::error(&e);
            }
        }
        // Deadline envelope: unwrap into (inner request, run options).
        // The wire decoder rejects nesting; in-process callers get the
        // lenient reading (innermost envelope wins).
        let mut req = req;
        let mut opts = RunOptions::default();
        while let Request::WithDeadline { deadline_ms, inner } = req {
            opts = RunOptions::with_deadline_ms(deadline_ms);
            req = *inner;
        }
        // Admission: data-plane requests hold a permit while they
        // execute; control-plane traffic (status, labels, lifecycle) is
        // never shed — operators must be able to inspect an overloaded
        // server.
        let admitted_model = match &req {
            Request::Predict { spec, .. }
            | Request::Classify { spec, .. }
            | Request::Regress { spec, .. }
            | Request::MultiInference { spec, .. } => Some(spec.name.clone()),
            Request::Lookup { table, .. } => Some(table.clone()),
            _ => None,
        };
        let _permit = match admitted_model {
            Some(model) => match self.admission.admit(&model) {
                Ok(permit) => Some(permit),
                Err(e) => {
                    let api = api_of(&req);
                    self.registry.counter(&format!("rpc.{api}.requests")).inc();
                    self.registry.counter(&format!("rpc.{api}.errors")).inc();
                    return Response::error(&e);
                }
            },
            None => None,
        };
        // Label-aware lookups: labeled specs resolve through the
        // resolver, unlabeled ones pass straight to the AVM.
        let labeled = LabeledSource {
            inner: self.avm.as_ref(),
            labels: self.labels.as_ref(),
        };
        // Health attribution: the inference arms consume their specs,
        // so clone the spec up front for per-(model, version) windowed
        // outcome recording after the dispatch below.
        let health_spec = match &req {
            Request::Predict { spec, .. }
            | Request::Classify { spec, .. }
            | Request::Regress { spec, .. }
            | Request::MultiInference { spec, .. } => Some(spec.clone()),
            _ => None,
        };
        let (api, resp) = match req {
            // Unwrapped above; a bare nested envelope can only be
            // constructed in-process and is answered, not panicked on.
            Request::WithDeadline { .. } => (
                "with_deadline",
                Response::Error {
                    kind: ErrorKind::InvalidArgument,
                    message: "nested deadline envelope".into(),
                },
            ),
            Request::Ping => ("ping", Response::Pong),
            Request::Predict { spec, signature, inputs } => {
                let model = spec.name.clone();
                let preq = PredictRequest { spec, signature, inputs };
                // Batch-size stats for /metrics and the Status dump.
                if let Some((_, input)) = preq.inputs.first() {
                    self.registry
                        .histogram("predict.batch_rows")
                        .record(input.batch() as u64);
                }
                // The serving path always executes through the session
                // registry: concurrent predicts (RPC and REST alike)
                // merge into shared device batches.
                let r = predict_with_opts(&labeled, self.sessions.as_ref(), &preq, &opts);
                // The decoded request buffers came from the global
                // pool; hand them back now that inference consumed them.
                for (_, input) in preq.inputs {
                    input.recycle_into(&crate::util::pool::BufferPool::global());
                }
                (
                    "predict",
                    match r {
                        Ok(r) => {
                            self.log(&model, r.model_version, &r);
                            Response::Predict {
                                model_version: r.model_version,
                                outputs: r.outputs,
                            }
                        }
                        Err(e) => Response::error(&e),
                    },
                )
            }
            Request::Classify { spec, signature, examples } => {
                let r = classify_with_opts(
                    &labeled,
                    self.sessions.as_ref(),
                    &ClassifyRequest { spec, signature, examples },
                    &opts,
                );
                (
                    "classify",
                    match r {
                        Ok(r) => Response::Classify {
                            model_version: r.model_version,
                            classes: r.results.iter().map(|c| c.class).collect(),
                            log_probs: r.results.into_iter().map(|c| c.log_probs).collect(),
                        },
                        Err(e) => Response::error(&e),
                    },
                )
            }
            Request::Regress { spec, signature, examples } => {
                let r = regress_with_opts(
                    &labeled,
                    self.sessions.as_ref(),
                    &RegressRequest { spec, signature, examples },
                    &opts,
                );
                (
                    "regress",
                    match r {
                        Ok(r) => Response::Regress {
                            model_version: r.model_version,
                            values: r.values,
                        },
                        Err(e) => Response::error(&e),
                    },
                )
            }
            Request::MultiInference { spec, tasks, examples } => {
                // The shared execution routes through the per-model
                // session too, so concurrent MultiInference calls
                // merge (ROADMAP: "Batching for MultiInference").
                let r = multi_inference_with_opts(
                    &labeled,
                    self.sessions.as_ref(),
                    &MultiInferenceRequest { spec, tasks, examples },
                    &opts,
                );
                (
                    "multi_inference",
                    match r {
                        Ok(r) => Response::MultiInference {
                            model_version: r.model_version,
                            results: r.results,
                        },
                        Err(e) => Response::error(&e),
                    },
                )
            }
            Request::GetModelMetadata { spec } => {
                ("get_model_metadata", self.model_metadata(&spec))
            }
            Request::SetVersionLabel { model, label, version } => {
                // Only loaded-and-serving versions may carry a label.
                let serving = self.avm.basic().ready_versions(&model);
                (
                    "set_version_label",
                    match self.labels.set(&model, &label, version, &serving) {
                        Ok(prev) => {
                            // The ready-set snapshot above can race a
                            // concurrent unload whose GC event fired
                            // before our insert; re-check so the label
                            // never outlives the version it points at.
                            // Best-effort: an unload that has published
                            // Unloading but not yet left the serving
                            // map can still slip past both checks —
                            // its Disabled-event GC is the backstop
                            // that keeps the end state consistent
                            // (label dropped, never dangling).
                            if self.avm.basic().ready_versions(&model).contains(&version) {
                                // Durable write-through; memory rolls
                                // back on persist failure so the two
                                // never disagree about a durable label.
                                match self.persist_label(&model, &label, Some(version)) {
                                    Ok(()) => Response::Ack,
                                    Err(e) => {
                                        let restore = prev.filter(|p| {
                                            self.avm
                                                .basic()
                                                .ready_versions(&model)
                                                .contains(p)
                                        });
                                        self.labels.rollback(&model, &label, version, restore);
                                        Response::Error {
                                            kind: ErrorKind::Internal,
                                            message: format!("label persist failed: {e:#}"),
                                        }
                                    }
                                }
                            } else {
                                // Compare-and-rollback: restore the
                                // prior mapping if that version still
                                // serves, else drop the label; a
                                // concurrent re-label is left alone.
                                let restore = prev.filter(|p| {
                                    self.avm.basic().ready_versions(&model).contains(p)
                                });
                                self.labels.rollback(&model, &label, version, restore);
                                Response::Error {
                                    kind: ErrorKind::FailedPrecondition,
                                    message: format!(
                                        "cannot label {model}:{version} as '{label}': \
                                         version unloaded concurrently"
                                    ),
                                }
                            }
                        }
                        Err(e) => Response::error(&e),
                    },
                )
            }
            Request::DeleteVersionLabel { model, label } => (
                "delete_version_label",
                {
                    // The store may hold a label memory has GC'd (its
                    // version unloaded); deleting that is still a hit.
                    let in_memory = self.labels.remove(&model, &label);
                    let in_store = self.label_store.as_ref().map_or(false, |s| {
                        s.get(&format!("label/{model}/{label}")).is_some()
                    });
                    if in_memory || in_store {
                        match self.persist_label(&model, &label, None) {
                            Ok(()) => Response::Ack,
                            Err(e) => Response::Error {
                                kind: ErrorKind::Internal,
                                message: format!("label persist failed: {e:#}"),
                            },
                        }
                    } else {
                        Response::Error {
                            kind: ErrorKind::NotFound,
                            message: format!(
                                "model '{model}' has no version labeled '{label}'"
                            ),
                        }
                    }
                },
            ),
            Request::Lookup { table, key } => (
                "lookup",
                match self
                    .avm
                    .handle::<TableServable>(&table, VersionRequest::Latest)
                {
                    Ok(h) => Response::Lookup {
                        values: h.lookup(&key).map(|v| v.to_vec()),
                    },
                    Err(e) => Response::error(&e),
                },
            ),
            Request::SetAspired { model, versions } => {
                // Footnote 6: the RPC-based Source for TFS². The
                // Synchronizer pins exact versions; artifacts still come
                // from the shared filesystem.
                self.set_serving_policy(&model, ServingPolicy::Specific(versions));
                ("set_aspired", Response::Ack)
            }
            Request::ModelStatus { model } => {
                let snapshot = self.avm.monitor().snapshot();
                let versions = snapshot
                    .into_iter()
                    .filter(|(id, _)| id.name == model)
                    .map(|(id, st)| (id.version, st.describe()))
                    .collect();
                ("model_status", Response::ModelStatus { versions })
            }
            Request::Metrics => {
                // Structured counterpart of Status: the Synchronizer
                // scrapes these samples (lane depth, queue delay, shed
                // counts) to drive fleet autoscaling without parsing
                // the human-oriented text dump.
                ("metrics", Response::Metrics { samples: self.registry.samples() })
            }
            Request::SetRolloutStatus { model, status } => {
                // Pushed by the fleet rollout engine after each
                // evaluation tick; an empty status clears the entry.
                // Purely informational — surfaced in `GET /v1/models`.
                let mut map = self.rollout_status.lock().unwrap();
                if status.is_empty() {
                    map.remove(&model);
                } else {
                    map.insert(model, status);
                }
                ("set_rollout_status", Response::Ack)
            }
            Request::Status => {
                // Snapshot buffer-pool state into gauges so the dump
                // shows the zero-allocation hot path working.
                crate::util::pool::BufferPool::global().export(&self.registry, "tensor_pool");
                crate::util::pool::BufferPool::global_i32()
                    .export(&self.registry, "tensor_pool_i32");
                let mut text = self.registry.dump();
                text.push_str(&format!(
                    "pooled_buffer_bytes {}\n",
                    crate::util::mem::pooled_buffer_bytes()
                ));
                text.push_str(&format!("ready {:?}\n", self.avm.basic().all_ready()));
                ("status", Response::Status { text })
            }
        };
        // Per-(model, version) windowed health: the rollout engine
        // gates canaries on *recent* error-rate and p99, so outcomes
        // land in rotating windows keyed by the version that served
        // (or would have served) the request. Server-side errors only:
        // client mistakes (bad signature, invalid argument) and
        // retryable shedding must not trip a rollback.
        if let Some(spec) = health_spec {
            let version = match &resp {
                Response::Predict { model_version, .. }
                | Response::Classify { model_version, .. }
                | Response::Regress { model_version, .. }
                | Response::MultiInference { model_version, .. } => Some(*model_version),
                // Errors carry no version: attribute via the spec's
                // pin/label, falling back to the newest ready version
                // (what Latest would have resolved to).
                _ => crate::inference::predict::resolve_spec_version(&self.labels, &spec)
                    .ok()
                    .flatten()
                    .or_else(|| {
                        self.avm.basic().ready_versions(&spec.name).into_iter().max()
                    }),
            };
            if let Some(v) = version {
                let base = format!("health.{}.v{v}", spec.name);
                self.registry
                    .windowed_counter(&format!("{base}.requests.window"))
                    .inc();
                if let Response::Error { kind, .. } = &resp {
                    if matches!(kind, ErrorKind::Internal | ErrorKind::DeadlineExceeded) {
                        self.registry
                            .windowed_counter(&format!("{base}.errors.window"))
                            .inc();
                    }
                }
                self.registry
                    .windowed_histogram(&format!("{base}.latency_ns.window"))
                    .record_duration(t0.elapsed());
            }
        }
        self.registry.counter(&format!("rpc.{api}.requests")).inc();
        if matches!(resp, Response::Error { .. }) {
            self.registry.counter(&format!("rpc.{api}.errors")).inc();
        }
        self.registry
            .histogram(&format!("rpc.{api}.latency_ns"))
            .record_duration(t0.elapsed());
        resp
    }

    /// Rollout status last pushed for `model` via `SetRolloutStatus`
    /// (`None` when no rollout has touched this replica).
    pub fn rollout_status_of(&self, model: &str) -> Option<String> {
        self.rollout_status.lock().unwrap().get(model).cloned()
    }

    /// Write-through for the durable label store: `Some(version)`
    /// upserts, `None` deletes. A no-op without `label_store_path`.
    fn persist_label(&self, model: &str, label: &str, version: Option<u64>) -> Result<()> {
        let Some(store) = &self.label_store else { return Ok(()) };
        let key = format!("label/{model}/{label}");
        store.txn(|t| {
            match version {
                Some(v) => t.put(&key, Json::Num(v as f64)),
                None => t.delete(&key),
            }
            Ok(())
        })
    }

    fn log(&self, model: &str, version: u64, resp: &crate::inference::predict::PredictResponse) {
        let digest = resp
            .outputs
            .first()
            .and_then(|(_, o)| o.as_f32().ok())
            .map(|t| digest_f32s(t.data()))
            .unwrap_or(0);
        self.logger.observe(model, version, 0, digest);
    }

    /// `GetModelMetadata`: per-version state, labels, and signature
    /// defs. A pinned version or label narrows the report to that one
    /// version; otherwise every version the monitor knows is listed.
    fn model_metadata(&self, spec: &ModelSpec) -> Response {
        let mut states: std::collections::BTreeMap<u64, String> = self
            .avm
            .monitor()
            .snapshot()
            .into_iter()
            .filter(|(id, _)| id.name == spec.name)
            .map(|(id, st)| (id.version, st.describe()))
            .collect();
        // Same version/label resolution rule as the lookup path.
        let wanted: Vec<u64> =
            match crate::inference::predict::resolve_spec_version(&self.labels, spec) {
                Err(e) => return Response::error(&e),
                Ok(Some(v)) => {
                    if !states.contains_key(&v) {
                        return Response::Error {
                            kind: ErrorKind::NotFound,
                            message: format!("model '{}' has no version {v}", spec.name),
                        };
                    }
                    vec![v]
                }
                Ok(None) => states.keys().copied().collect(),
            };
        if wanted.is_empty() {
            return Response::Error {
                kind: ErrorKind::NotFound,
                message: format!("model '{}' has no versions", spec.name),
            };
        }
        let versions = wanted
            .into_iter()
            .map(|v| {
                // Signatures come from the servable itself; non-HLO
                // platforms (tables) have none to report.
                let signatures = self
                    .avm
                    .handle::<HloServable>(&spec.name, VersionRequest::Specific(v))
                    .map(|h| {
                        h.signatures()
                            .iter()
                            .map(|(k, s)| (k.clone(), s.clone()))
                            .collect()
                    })
                    .unwrap_or_default();
                VersionMetadata {
                    version: v,
                    state: states.remove(&v).unwrap_or_else(|| "unknown".into()),
                    labels: self.labels.labels_of_version(&spec.name, v),
                    signatures,
                }
            })
            .collect();
        Response::ModelMetadata { model: spec.name.clone(), versions }
    }
}

/// Wire-API name of a request (metrics keys; matches the `(api, _)`
/// labels in [`ServerCore::handle`]).
fn api_of(req: &Request) -> &'static str {
    match req {
        Request::Ping => "ping",
        Request::Predict { .. } => "predict",
        Request::Classify { .. } => "classify",
        Request::Regress { .. } => "regress",
        Request::MultiInference { .. } => "multi_inference",
        Request::GetModelMetadata { .. } => "get_model_metadata",
        Request::SetVersionLabel { .. } => "set_version_label",
        Request::DeleteVersionLabel { .. } => "delete_version_label",
        Request::Lookup { .. } => "lookup",
        Request::SetAspired { .. } => "set_aspired",
        Request::ModelStatus { .. } => "model_status",
        Request::Status => "status",
        Request::Metrics => "metrics",
        Request::SetRolloutStatus { .. } => "set_rollout_status",
        Request::WithDeadline { .. } => "with_deadline",
    }
}

/// Helper: build a classify/regress example from a raw feature vector.
pub fn example_from_features(x: Vec<f32>) -> crate::inference::example::Example {
    crate::inference::example::Example::new().with("x", Feature::Floats(x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::tensor::Tensor;
    use crate::rpc::client::RpcClient;
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};

    fn test_config() -> ServerConfig {
        ServerConfig {
            port: 0,
            http_addr: None,
            artifacts_root: default_artifacts_root(),
            poll_interval: Some(Duration::from_millis(50)),
            availability_preserving: true,
            load_threads: 2,
            ram_capacity_bytes: 0,
            batching: Default::default(),
            models: vec![
                super::super::config::ModelConfig {
                    name: "mlp_classifier".into(),
                    platform: "hlo".into(),
                    base_path: default_artifacts_root().join("mlp_classifier"),
                    policy: ServingPolicy::Latest(1),
                },
                super::super::config::ModelConfig {
                    name: "toy_table".into(),
                    platform: "table".into(),
                    base_path: default_artifacts_root().join("toy_table"),
                    policy: ServingPolicy::Latest(1),
                },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn full_server_serves_both_platforms() {
        if !artifacts_available() {
            return;
        }
        let server = ModelServer::start(test_config()).unwrap();
        server.wait_until_ready(Duration::from_secs(60)).unwrap();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();

        // HLO platform over RPC (legacy single-tensor Predict form).
        let resp = client
            .call_ok(&Request::predict(
                "mlp_classifier",
                None,
                Tensor::zeros(vec![2, 32]),
            ))
            .unwrap();
        match resp {
            Response::Predict { model_version, outputs } => {
                assert_eq!(model_version, 2); // latest
                assert_eq!(outputs.len(), 2);
                assert_eq!(outputs[0].0, "log_probs");
                assert_eq!(outputs[1].0, "class");
            }
            other => panic!("unexpected {other:?}"),
        }

        // BananaFlow platform over the same server.
        let resp = client
            .call_ok(&Request::Lookup { table: "toy_table".into(), key: "3".into() })
            .unwrap();
        assert_eq!(resp, Response::Lookup { values: Some(vec![3.0, 2.0]) });

        // Status carries metrics.
        match client.call_ok(&Request::Status).unwrap() {
            Response::Status { text } => {
                assert!(text.contains("rpc.predict.requests 1"), "{text}");
            }
            other => panic!("unexpected {other:?}"),
        }
        server.stop();
    }

    #[test]
    fn rpc_driven_aspired_versions() {
        if !artifacts_available() {
            return;
        }
        let server = ModelServer::start(test_config()).unwrap();
        server.wait_until_ready(Duration::from_secs(60)).unwrap();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        // Pin version 1 via the RPC source (the TFS² path).
        client
            .call_ok(&Request::SetAspired {
                model: "mlp_classifier".into(),
                versions: vec![1],
            })
            .unwrap();
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let ready = server.avm().basic().ready_versions("mlp_classifier");
            if ready == vec![1] {
                break;
            }
            assert!(Instant::now() < deadline, "never pinned to v1: {ready:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
        // Model status over RPC reflects the transition.
        match client
            .call_ok(&Request::ModelStatus { model: "mlp_classifier".into() })
            .unwrap()
        {
            Response::ModelStatus { versions } => {
                assert!(versions.iter().any(|(v, s)| *v == 1 && s == "ready"));
            }
            other => panic!("unexpected {other:?}"),
        }
        server.stop();
    }

    // ------------------------------------------------- synthetic e2e
    //
    // These run in every build (no artifacts, no PJRT backend): the
    // synthetic engine serves real signatures through the real
    // lifecycle + RPC stack.

    use crate::base::servable::ServableId;
    use crate::inference::multi::{HeadResult, InferenceTask};
    use crate::runtime::artifacts::ArtifactSpec;
    use crate::runtime::hlo_servable::synthetic_loader;

    fn empty_config() -> ServerConfig {
        ServerConfig {
            port: 0,
            http_addr: None,
            artifacts_root: std::env::temp_dir(),
            poll_interval: None,
            availability_preserving: true,
            load_threads: 2,
            ram_capacity_bytes: 0,
            batching: Default::default(),
            models: vec![],
            ..Default::default()
        }
    }

    /// A running server with synthetic multi-head versions of "syn"
    /// loaded straight into the manager.
    fn synthetic_server(versions: &[u64]) -> Arc<ModelServer> {
        let server = ModelServer::start(empty_config()).unwrap();
        for &v in versions {
            server
                .avm()
                .basic()
                .load_and_wait(
                    ServableId::new("syn", v),
                    synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", v, 8, 3)),
                    Duration::from_secs(30),
                )
                .unwrap();
        }
        server
    }

    #[test]
    fn labeled_predict_resolves_canary_and_stable() {
        let server = synthetic_server(&[1, 2]);
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();

        // Labels attach only to loaded-and-serving versions.
        client
            .call_ok(&Request::SetVersionLabel {
                model: "syn".into(),
                label: "stable".into(),
                version: 1,
            })
            .unwrap();
        client
            .call_ok(&Request::SetVersionLabel {
                model: "syn".into(),
                label: "canary".into(),
                version: 2,
            })
            .unwrap();
        let err = client
            .call_ok(&Request::SetVersionLabel {
                model: "syn".into(),
                label: "next".into(),
                version: 9,
            })
            .unwrap_err();
        assert!(err.to_string().contains("not loaded and serving"), "{err}");

        // The same labeled Predict resolves to different versions.
        for (label, want) in [("stable", 1u64), ("canary", 2)] {
            let resp = client
                .call_ok(&Request::Predict {
                    spec: crate::inference::ModelSpec::with_label("syn", label),
                    signature: String::new(),
                    inputs: vec![("x".into(), Tensor::zeros(vec![2, 8]))],
                })
                .unwrap();
            match resp {
                Response::Predict { model_version, outputs } => {
                    assert_eq!(model_version, want, "label {label}");
                    assert_eq!(outputs[0].0, "log_probs");
                    assert_eq!(outputs[1].0, "class");
                }
                other => panic!("unexpected {other:?}"),
            }
        }

        // Unknown label is a clear error, not a silent fallback.
        let err = client
            .call_ok(&Request::Predict {
                spec: crate::inference::ModelSpec::with_label("syn", "ghost"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            })
            .unwrap_err();
        assert!(err.to_string().contains("ghost"), "{err}");

        // Named-input validation errors name the offending tensor.
        let err = client
            .call_ok(&Request::Predict {
                spec: crate::inference::ModelSpec::latest("syn"),
                signature: String::new(),
                inputs: vec![("bogus".into(), Tensor::zeros(vec![1, 8]))],
            })
            .unwrap_err();
        assert!(err.to_string().contains("bogus"), "{err}");
        let err = client
            .call_ok(&Request::Predict {
                spec: crate::inference::ModelSpec::latest("syn"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 5]))],
            })
            .unwrap_err();
        assert!(err.to_string().contains("'x'"), "{err}");
        server.stop();
    }

    #[test]
    fn get_model_metadata_reports_signatures_and_labels() {
        let server = synthetic_server(&[1, 2]);
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        client
            .call_ok(&Request::SetVersionLabel {
                model: "syn".into(),
                label: "canary".into(),
                version: 2,
            })
            .unwrap();

        match client
            .call_ok(&Request::GetModelMetadata {
                spec: crate::inference::ModelSpec::latest("syn"),
            })
            .unwrap()
        {
            Response::ModelMetadata { model, versions } => {
                assert_eq!(model, "syn");
                assert_eq!(versions.len(), 2);
                let v2 = versions.iter().find(|v| v.version == 2).unwrap();
                assert_eq!(v2.state, "ready");
                assert_eq!(v2.labels, vec!["canary".to_string()]);
                let names: Vec<&str> =
                    v2.signatures.iter().map(|(n, _)| n.as_str()).collect();
                assert!(names.contains(&"serving_default"), "{names:?}");
                let (_, reg) =
                    v2.signatures.iter().find(|(n, _)| n == "regress").unwrap();
                assert_eq!(reg.method, "regress");
                assert_eq!(reg.inputs[0].name, "x");
                assert_eq!(reg.inputs[0].shape, vec![-1, 8]);
                assert_eq!(reg.outputs[0].name, "value");
            }
            other => panic!("unexpected {other:?}"),
        }

        // A labeled metadata request narrows to the labeled version.
        match client
            .call_ok(&Request::GetModelMetadata {
                spec: crate::inference::ModelSpec::with_label("syn", "canary"),
            })
            .unwrap()
        {
            Response::ModelMetadata { versions, .. } => {
                assert_eq!(versions.len(), 1);
                assert_eq!(versions[0].version, 2);
            }
            other => panic!("unexpected {other:?}"),
        }

        // Unknown model, unknown pinned version, and version+label
        // together all error.
        assert!(client
            .call_ok(&Request::GetModelMetadata {
                spec: crate::inference::ModelSpec::latest("ghost"),
            })
            .is_err());
        assert!(client
            .call_ok(&Request::GetModelMetadata {
                spec: crate::inference::ModelSpec::at_version("syn", 99),
            })
            .is_err());
        let mut both = crate::inference::ModelSpec::with_label("syn", "canary");
        both.version = Some(2);
        let err = client
            .call_ok(&Request::GetModelMetadata { spec: both })
            .unwrap_err();
        assert!(err.to_string().contains("use one"), "{err}");
        server.stop();
    }

    #[test]
    fn delete_version_label_over_rpc() {
        let server = synthetic_server(&[1]);
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        client
            .call_ok(&Request::SetVersionLabel {
                model: "syn".into(),
                label: "stable".into(),
                version: 1,
            })
            .unwrap();
        // Labeled predict works while the label exists…
        client
            .call_ok(&Request::Predict {
                spec: crate::inference::ModelSpec::with_label("syn", "stable"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            })
            .unwrap();
        // …deleting it is an Ack, and the label is gone.
        client
            .call_ok(&Request::DeleteVersionLabel {
                model: "syn".into(),
                label: "stable".into(),
            })
            .unwrap();
        let err = client
            .call_ok(&Request::Predict {
                spec: crate::inference::ModelSpec::with_label("syn", "stable"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            })
            .unwrap_err();
        assert!(err.to_string().contains("stable"), "{err}");
        // Deleting a label that does not exist is a clear error.
        let err = client
            .call_ok(&Request::DeleteVersionLabel {
                model: "syn".into(),
                label: "stable".into(),
            })
            .unwrap_err();
        assert!(err.to_string().contains("no version labeled"), "{err}");
        server.stop();
    }

    #[test]
    fn labels_gc_when_their_version_unloads() {
        let server = synthetic_server(&[1, 2]);
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        for (label, version) in [("stable", 1u64), ("canary", 2)] {
            client
                .call_ok(&Request::SetVersionLabel {
                    model: "syn".into(),
                    label: label.into(),
                    version,
                })
                .unwrap();
        }
        // Unload v1: its label must be dropped, not left dangling.
        server
            .avm()
            .basic()
            .unload_and_wait(ServableId::new("syn", 1), Duration::from_secs(30))
            .unwrap();
        let err = client
            .call_ok(&Request::Predict {
                spec: crate::inference::ModelSpec::with_label("syn", "stable"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            })
            .unwrap_err();
        assert!(
            err.to_string().contains("no version labeled"),
            "stale label survived unload: {err}"
        );
        // v2's label is untouched.
        let resp = client
            .call_ok(&Request::Predict {
                spec: crate::inference::ModelSpec::with_label("syn", "canary"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            })
            .unwrap();
        match resp {
            Response::Predict { model_version, .. } => assert_eq!(model_version, 2),
            other => panic!("unexpected {other:?}"),
        }
        // Metadata agrees: no version reports the GC'd label.
        match client
            .call_ok(&Request::GetModelMetadata {
                spec: crate::inference::ModelSpec::latest("syn"),
            })
            .unwrap()
        {
            Response::ModelMetadata { versions, .. } => {
                assert!(versions
                    .iter()
                    .all(|v| !v.labels.contains(&"stable".to_string())));
            }
            other => panic!("unexpected {other:?}"),
        }
        server.stop();
    }

    #[test]
    fn deadline_envelope_and_drain_over_rpc() {
        use crate::base::error::ErrorKind;
        let server = synthetic_server(&[1]);
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        let predict = || Request::Predict {
            spec: crate::inference::ModelSpec::latest("syn"),
            signature: String::new(),
            inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
        };
        // An already-expired deadline is answered DeadlineExceeded
        // without touching the device.
        let err = client.call_ok(&predict().with_deadline_ms(0)).unwrap_err();
        assert_eq!(ErrorKind::of(&err), ErrorKind::DeadlineExceeded, "{err}");
        // A generous one serves normally.
        assert!(matches!(
            client.call_ok(&predict().with_deadline_ms(30_000)).unwrap(),
            Response::Predict { .. }
        ));
        // Draining refuses new data-plane work retryably while the
        // control plane stays reachable.
        server.core().admission.start_draining();
        let err = client.call_ok(&predict()).unwrap_err();
        assert_eq!(ErrorKind::of(&err), ErrorKind::Unavailable, "{err}");
        assert!(err.to_string().contains("draining"), "{err}");
        assert!(matches!(
            client
                .call_ok(&Request::ModelStatus { model: "syn".into() })
                .unwrap(),
            Response::ModelStatus { .. }
        ));
        server.stop();
    }

    #[test]
    fn multi_inference_two_heads_over_rpc() {
        let server = synthetic_server(&[2]);
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        let examples: Vec<_> = (0..3)
            .map(|i| {
                example_from_features((0..8).map(|j| ((i * 8 + j) as f32) * 0.1).collect())
            })
            .collect();

        let resp = client
            .call_ok(&Request::MultiInference {
                spec: crate::inference::ModelSpec::latest("syn"),
                tasks: vec![
                    InferenceTask::classify("classify"),
                    InferenceTask::regress("regress"),
                ],
                examples: examples.clone(),
            })
            .unwrap();
        let multi_classes = match resp {
            Response::MultiInference { model_version, results } => {
                assert_eq!(model_version, 2);
                assert_eq!(results.len(), 2);
                assert_eq!(results[0].0, "classify");
                assert_eq!(results[1].0, "regress");
                match &results[1].1 {
                    HeadResult::Regress { values } => assert_eq!(values.len(), 3),
                    other => panic!("unexpected {other:?}"),
                }
                match &results[0].1 {
                    HeadResult::Classify { classes, log_probs } => {
                        assert_eq!(classes.len(), 3);
                        assert_eq!(log_probs.len(), 3);
                        classes.clone()
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            other => panic!("unexpected {other:?}"),
        };

        // The classify head agrees with a standalone Classify call
        // through the same server.
        match client
            .call_ok(&Request::Classify {
                spec: crate::inference::ModelSpec::latest("syn"),
                signature: "classify".into(),
                examples,
            })
            .unwrap()
        {
            Response::Classify { classes, .. } => assert_eq!(classes, multi_classes),
            other => panic!("unexpected {other:?}"),
        }

        // A task against a missing signature fails the whole request
        // with a clear error.
        let err = client
            .call_ok(&Request::MultiInference {
                spec: crate::inference::ModelSpec::latest("syn"),
                tasks: vec![InferenceTask::classify("ghost")],
                examples: vec![example_from_features(vec![0.0; 8])],
            })
            .unwrap_err();
        assert!(err.to_string().contains("ghost"), "{err}");
        server.stop();
    }

    #[test]
    fn metrics_rpc_returns_structured_samples() {
        let server = synthetic_server(&[1]);
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        client
            .call_ok(&Request::Predict {
                spec: crate::inference::ModelSpec::latest("syn"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            })
            .unwrap();
        match client.call_ok(&Request::Metrics).unwrap() {
            Response::Metrics { samples } => {
                let get = |name: &str| {
                    samples
                        .iter()
                        .find(|(n, _)| n == name)
                        .map(|(_, v)| *v)
                        .unwrap_or_else(|| panic!("no sample '{name}' in {samples:?}"))
                };
                assert!(get("rpc.predict.requests") >= 1.0);
                assert!(get("rpc.predict.latency_ns.count") >= 1.0);
                // Per-(model, version) windowed health series, keyed by
                // the version that served: what rollout gating scrapes.
                assert!(get("health.syn.v1.requests.window") >= 1.0);
                assert_eq!(get("health.syn.v1.errors.window"), 0.0);
                assert!(get("health.syn.v1.latency_ns.window.p99") > 0.0);
                // Name-sorted, so scrapers can binary-search or diff.
                let names: Vec<&String> = samples.iter().map(|(n, _)| n).collect();
                let mut sorted = names.clone();
                sorted.sort();
                assert_eq!(names, sorted);
            }
            other => panic!("unexpected {other:?}"),
        }
        server.stop();
    }

    #[test]
    fn rollout_status_push_and_clear() {
        let server = synthetic_server(&[1]);
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        assert_eq!(server.core().rollout_status_of("syn"), None);
        client
            .call_ok(&Request::SetRolloutStatus {
                model: "syn".into(),
                status: "ramping: step 2/4 (25%)".into(),
            })
            .unwrap();
        assert_eq!(
            server.core().rollout_status_of("syn").as_deref(),
            Some("ramping: step 2/4 (25%)")
        );
        // An empty status clears the entry (rollout finished).
        client
            .call_ok(&Request::SetRolloutStatus { model: "syn".into(), status: String::new() })
            .unwrap();
        assert_eq!(server.core().rollout_status_of("syn"), None);
        server.stop();
    }

    #[test]
    fn durable_labels_survive_server_restart() {
        let dir = std::env::temp_dir().join(format!("ts-label-store-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let config = ServerConfig {
            label_store_path: Some(dir.join("labels")),
            ..empty_config()
        };

        // First life: load two versions, label them, stop.
        let server = ModelServer::start(config.clone()).unwrap();
        for v in [1u64, 2] {
            server
                .avm()
                .basic()
                .load_and_wait(
                    ServableId::new("syn", v),
                    synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", v, 8, 3)),
                    Duration::from_secs(30),
                )
                .unwrap();
        }
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        for (label, version) in [("stable", 1u64), ("canary", 2)] {
            client
                .call_ok(&Request::SetVersionLabel {
                    model: "syn".into(),
                    label: label.into(),
                    version,
                })
                .unwrap();
        }
        // A deleted label must not resurrect after restart.
        client
            .call_ok(&Request::SetVersionLabel {
                model: "syn".into(),
                label: "doomed".into(),
                version: 2,
            })
            .unwrap();
        client
            .call_ok(&Request::DeleteVersionLabel {
                model: "syn".into(),
                label: "doomed".into(),
            })
            .unwrap();
        server.stop();
        drop(client);

        // Second life: same store path, fresh process state. Labels
        // re-attach as their versions reach Ready — no operator call.
        let server = ModelServer::start(config).unwrap();
        for v in [1u64, 2] {
            server
                .avm()
                .basic()
                .load_and_wait(
                    ServableId::new("syn", v),
                    synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", v, 8, 3)),
                    Duration::from_secs(30),
                )
                .unwrap();
        }
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        for (label, want) in [("stable", 1u64), ("canary", 2)] {
            match client
                .call_ok(&Request::Predict {
                    spec: crate::inference::ModelSpec::with_label("syn", label),
                    signature: String::new(),
                    inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
                })
                .unwrap()
            {
                Response::Predict { model_version, .. } => {
                    assert_eq!(model_version, want, "label {label} after restart")
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        let err = client
            .call_ok(&Request::Predict {
                spec: crate::inference::ModelSpec::with_label("syn", "doomed"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            })
            .unwrap_err();
        assert!(err.to_string().contains("doomed"), "{err}");
        server.stop();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
