//! [`ModelServer`]: the assembled canonical server.
//!
//! Wiring (paper Figure 1 made concrete):
//!
//! ```text
//! FileSystemSource ──► SourceRouter (by platform)
//!                        ├─ port 0 ─► HloSourceAdapter ──► AVM
//!                        └─ port 1 ─► TableSourceAdapter ─► AVM
//! RPC front end ──► Predict/Classify/Regress/Lookup over AVM handles
//!              └──► admin: SetAspired (RPC source), ModelStatus, Status
//! ```

use super::config::ServerConfig;
use crate::base::aspired::{AspiredVersionsCallback, Source};
use crate::inference::classify::{classify, ClassifyRequest};
use crate::inference::example::Feature;
use crate::inference::logger::{digest_f32s, RequestLogger};
use crate::inference::predict::{predict, PredictRequest};
use crate::inference::regress::{regress, RegressRequest};
use crate::inference::table::{table_source_adapter, TableServable};
use crate::lifecycle::basic_manager::{ManagerOptions, VersionRequest};
use crate::lifecycle::manager::{AspiredVersionsManager, AvmOptions};
use crate::lifecycle::policy::{
    AvailabilityPreservingPolicy, ResourcePreservingPolicy, VersionPolicy,
};
use crate::lifecycle::source::{FileSystemSource, ServingPolicy, WatchedServable};
use crate::lifecycle::source_router::SourceRouter;
use crate::rpc::proto::{Request, Response};
use crate::rpc::server::RpcServer;
use crate::runtime::hlo_servable::hlo_source_adapter;
use crate::runtime::pjrt::XlaRuntime;
use crate::util::metrics::Registry;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Handler-visible server state (shared with the RPC closure).
pub struct ServerCore {
    pub config: ServerConfig,
    avm: Arc<AspiredVersionsManager>,
    source: Arc<FileSystemSource>,
    pub registry: Arc<Registry>,
    pub logger: Arc<RequestLogger>,
}

/// The running canonical server.
pub struct ModelServer {
    core: Arc<ServerCore>,
    rpc: Arc<RpcServer>,
}

impl ModelServer {
    /// Build and start everything; returns once the RPC server is
    /// listening (models may still be loading — see
    /// [`ModelServer::wait_until_ready`]).
    pub fn start(config: ServerConfig) -> Result<Arc<Self>> {
        // Manager.
        let policy: Arc<dyn VersionPolicy> = if config.availability_preserving {
            Arc::new(AvailabilityPreservingPolicy)
        } else {
            Arc::new(ResourcePreservingPolicy)
        };
        let avm = AspiredVersionsManager::new(
            policy,
            AvmOptions {
                manager: ManagerOptions {
                    load_threads: config.load_threads,
                    ram_capacity_bytes: if config.ram_capacity_bytes == 0 {
                        None
                    } else {
                        Some(config.ram_capacity_bytes)
                    },
                    name: "server".into(),
                    ..Default::default()
                },
                reconcile_interval: Some(Duration::from_millis(20)),
            },
        );

        // Platform router + adapters (Figure 1). Models added at
        // runtime (TFS² SetAspired) aren't in the config map: sniff the
        // platform from the artifact layout (table.json ⇒ BananaFlow).
        // This runs on the lifecycle path, never per-request.
        let platform_of: HashMap<String, usize> = config
            .models
            .iter()
            .map(|m| (m.name.clone(), usize::from(m.platform == "table")))
            .collect();
        let sniff_root = config.artifacts_root.clone();
        let router = SourceRouter::<PathBuf>::new(2, move |name| {
            if let Some(&port) = platform_of.get(name) {
                return port;
            }
            let base = sniff_root.join(name);
            let is_table = crate::lifecycle::source::scan_versions(&base)
                .last()
                .map(|v| base.join(v.to_string()).join("table.json").exists())
                .unwrap_or(false);
            usize::from(is_table)
        });
        let runtime = XlaRuntime::shared()?;
        let hlo_adapter = hlo_source_adapter(runtime);
        let table_adapter = table_source_adapter();
        hlo_adapter.connect(Arc::clone(&avm) as Arc<dyn AspiredVersionsCallback<_>>);
        table_adapter.connect(Arc::clone(&avm) as Arc<dyn AspiredVersionsCallback<_>>);
        router.connect_port(0, hlo_adapter);
        router.connect_port(1, table_adapter);

        // File-system source.
        let watched = config
            .models
            .iter()
            .map(|m| WatchedServable {
                name: m.name.clone(),
                base_path: m.base_path.clone(),
                policy: m.policy.clone(),
            })
            .collect();
        let mut source = FileSystemSource::new(watched, config.poll_interval);
        source.set_aspired_versions_callback(router);

        let core = Arc::new(ServerCore {
            config: config.clone(),
            avm,
            source,
            registry: Registry::new(),
            logger: Arc::new(RequestLogger::new(0.1, 4096, 42)),
        });

        // RPC front end.
        let handler_core = Arc::clone(&core);
        let rpc = RpcServer::start(
            &format!("0.0.0.0:{}", config.port),
            Arc::new(move |req| handler_core.handle(req)),
        )?;
        Ok(Arc::new(ModelServer { core, rpc }))
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.rpc.addr()
    }

    pub fn core(&self) -> &Arc<ServerCore> {
        &self.core
    }

    pub fn avm(&self) -> &Arc<AspiredVersionsManager> {
        &self.core.avm
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.core.registry
    }

    /// Canary/rollback control (§2.1.1).
    pub fn set_serving_policy(&self, model: &str, policy: ServingPolicy) {
        self.core.set_serving_policy(model, policy);
    }

    /// Block until every configured model has at least one ready
    /// version (or timeout). Returns the ready map.
    pub fn wait_until_ready(&self, timeout: Duration) -> Result<HashMap<String, Vec<u64>>> {
        let deadline = Instant::now() + timeout;
        loop {
            let ready: HashMap<String, Vec<u64>> = self
                .core
                .config
                .models
                .iter()
                .map(|m| (m.name.clone(), self.core.avm.basic().ready_versions(&m.name)))
                .collect();
            if ready.values().all(|v| !v.is_empty()) {
                return Ok(ready);
            }
            if Instant::now() >= deadline {
                return Err(anyhow!("models not ready after {timeout:?}: {ready:?}"));
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    pub fn stop(&self) {
        self.rpc.stop();
    }
}

impl ServerCore {
    pub fn avm(&self) -> &Arc<AspiredVersionsManager> {
        &self.avm
    }

    /// Canary/rollback control (§2.1.1): change the serving policy for
    /// one model and re-poll immediately. Models not yet watched (TFS²
    /// assigns them at runtime) are added, served from
    /// `<artifacts_root>/<model>`.
    pub fn set_serving_policy(&self, model: &str, policy: ServingPolicy) {
        if !self.source.is_watching(model) {
            self.source.watch(crate::lifecycle::source::WatchedServable {
                name: model.to_string(),
                base_path: self.config.artifacts_root.join(model),
                policy: policy.clone(),
            });
        }
        self.source.set_policy(model, policy);
        self.source.poll_once();
    }

    /// The RPC request handler (one call per request frame).
    pub fn handle(&self, req: Request) -> Response {
        let t0 = Instant::now();
        let (api, resp) = match req {
            Request::Ping => ("ping", Response::Pong),
            Request::Predict { model, version, input } => {
                let preq = PredictRequest { model: model.clone(), version, input };
                let r = predict(self.avm.as_ref(), &preq);
                // The decoded request buffer came from the global pool;
                // hand it back now that inference has consumed it.
                preq.input
                    .recycle_into(&crate::util::pool::BufferPool::global());
                (
                    "predict",
                    match r {
                        Ok(r) => {
                            self.log(&model, r.model_version, &r);
                            Response::Predict {
                                model_version: r.model_version,
                                outputs: r.outputs,
                            }
                        }
                        Err(e) => Response::Error { message: e.to_string() },
                    },
                )
            }
            Request::Classify { model, version, examples } => {
                let r = classify(
                    self.avm.as_ref(),
                    &ClassifyRequest { model, version, examples },
                );
                (
                    "classify",
                    match r {
                        Ok(r) => Response::Classify {
                            model_version: r.model_version,
                            classes: r.results.iter().map(|c| c.class).collect(),
                            log_probs: r.results.into_iter().map(|c| c.log_probs).collect(),
                        },
                        Err(e) => Response::Error { message: e.to_string() },
                    },
                )
            }
            Request::Regress { model, version, examples } => {
                let r = regress(
                    self.avm.as_ref(),
                    &RegressRequest { model, version, examples },
                );
                (
                    "regress",
                    match r {
                        Ok(r) => Response::Regress {
                            model_version: r.model_version,
                            values: r.values,
                        },
                        Err(e) => Response::Error { message: e.to_string() },
                    },
                )
            }
            Request::Lookup { table, key } => (
                "lookup",
                match self
                    .avm
                    .handle::<TableServable>(&table, VersionRequest::Latest)
                {
                    Ok(h) => Response::Lookup {
                        values: h.lookup(&key).map(|v| v.to_vec()),
                    },
                    Err(e) => Response::Error { message: e.to_string() },
                },
            ),
            Request::SetAspired { model, versions } => {
                // Footnote 6: the RPC-based Source for TFS². The
                // Synchronizer pins exact versions; artifacts still come
                // from the shared filesystem.
                self.set_serving_policy(&model, ServingPolicy::Specific(versions));
                ("set_aspired", Response::Ack)
            }
            Request::ModelStatus { model } => {
                let snapshot = self.avm.monitor().snapshot();
                let versions = snapshot
                    .into_iter()
                    .filter(|(id, _)| id.name == model)
                    .map(|(id, st)| (id.version, st.label().to_string()))
                    .collect();
                ("model_status", Response::ModelStatus { versions })
            }
            Request::Status => {
                // Snapshot buffer-pool state into gauges so the dump
                // shows the zero-allocation hot path working.
                crate::util::pool::BufferPool::global().export(&self.registry, "tensor_pool");
                let mut text = self.registry.dump();
                text.push_str(&format!(
                    "pooled_buffer_bytes {}\n",
                    crate::util::mem::pooled_buffer_bytes()
                ));
                text.push_str(&format!("ready {:?}\n", self.avm.basic().all_ready()));
                ("status", Response::Status { text })
            }
        };
        self.registry.counter(&format!("rpc.{api}.requests")).inc();
        if matches!(resp, Response::Error { .. }) {
            self.registry.counter(&format!("rpc.{api}.errors")).inc();
        }
        self.registry
            .histogram(&format!("rpc.{api}.latency_ns"))
            .record_duration(t0.elapsed());
        resp
    }

    fn log(&self, model: &str, version: u64, resp: &crate::inference::predict::PredictResponse) {
        let digest = resp
            .outputs
            .first()
            .and_then(|o| o.as_f32().ok())
            .map(|t| digest_f32s(t.data()))
            .unwrap_or(0);
        self.logger.observe(model, version, 0, digest);
    }
}

/// Helper: build a classify/regress example from a raw feature vector.
pub fn example_from_features(x: Vec<f32>) -> crate::inference::example::Example {
    crate::inference::example::Example::new().with("x", Feature::Floats(x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::tensor::Tensor;
    use crate::rpc::client::RpcClient;
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};

    fn test_config() -> ServerConfig {
        ServerConfig {
            port: 0,
            artifacts_root: default_artifacts_root(),
            poll_interval: Some(Duration::from_millis(50)),
            availability_preserving: true,
            load_threads: 2,
            ram_capacity_bytes: 0,
            models: vec![
                super::super::config::ModelConfig {
                    name: "mlp_classifier".into(),
                    platform: "hlo".into(),
                    base_path: default_artifacts_root().join("mlp_classifier"),
                    policy: ServingPolicy::Latest(1),
                },
                super::super::config::ModelConfig {
                    name: "toy_table".into(),
                    platform: "table".into(),
                    base_path: default_artifacts_root().join("toy_table"),
                    policy: ServingPolicy::Latest(1),
                },
            ],
        }
    }

    #[test]
    fn full_server_serves_both_platforms() {
        if !artifacts_available() {
            return;
        }
        let server = ModelServer::start(test_config()).unwrap();
        server.wait_until_ready(Duration::from_secs(60)).unwrap();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();

        // HLO platform over RPC.
        let resp = client
            .call_ok(&Request::Predict {
                model: "mlp_classifier".into(),
                version: None,
                input: Tensor::zeros(vec![2, 32]),
            })
            .unwrap();
        match resp {
            Response::Predict { model_version, outputs } => {
                assert_eq!(model_version, 2); // latest
                assert_eq!(outputs.len(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }

        // BananaFlow platform over the same server.
        let resp = client
            .call_ok(&Request::Lookup { table: "toy_table".into(), key: "3".into() })
            .unwrap();
        assert_eq!(resp, Response::Lookup { values: Some(vec![3.0, 2.0]) });

        // Status carries metrics.
        match client.call_ok(&Request::Status).unwrap() {
            Response::Status { text } => {
                assert!(text.contains("rpc.predict.requests 1"), "{text}");
            }
            other => panic!("unexpected {other:?}"),
        }
        server.stop();
    }

    #[test]
    fn rpc_driven_aspired_versions() {
        if !artifacts_available() {
            return;
        }
        let server = ModelServer::start(test_config()).unwrap();
        server.wait_until_ready(Duration::from_secs(60)).unwrap();
        let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
        // Pin version 1 via the RPC source (the TFS² path).
        client
            .call_ok(&Request::SetAspired {
                model: "mlp_classifier".into(),
                versions: vec![1],
            })
            .unwrap();
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let ready = server.avm().basic().ready_versions("mlp_classifier");
            if ready == vec![1] {
                break;
            }
            assert!(Instant::now() < deadline, "never pinned to v1: {ready:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
        // Model status over RPC reflects the transition.
        match client
            .call_ok(&Request::ModelStatus { model: "mlp_classifier".into() })
            .unwrap()
        {
            Response::ModelStatus { versions } => {
                assert!(versions.iter().any(|(v, s)| *v == 1 && s == "ready"));
            }
            other => panic!("unexpected {other:?}"),
        }
        server.stop();
    }
}
