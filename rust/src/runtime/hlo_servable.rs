//! [`HloServable`]: the "TensorFlow platform" of this reproduction —
//! one compiled executable per allowed batch size plus its spec — and
//! the [`HloLoader`]/[`hlo_source_adapter`] that plug it into the
//! lifecycle chain (§2.1's TensorFlow Source Adapter analogue).
//!
//! A servable also exposes its callable surface as a map of named
//! [`SignatureDef`]s ([`HloServable::signatures`]) derived from the
//! artifact metadata — what `GetModelMetadata` reports and what the
//! inference layer validates named inputs against.
//!
//! Besides the compiled engine there is a **synthetic** engine
//! ([`HloServable::synthetic`] / [`synthetic_loader`]): a pure-Rust
//! deterministic model that honors the same spec/signature contract.
//! It lets the full serving stack — lifecycle, RPC, signatures,
//! labels, MultiInference — run end-to-end in builds without the PJRT
//! backend or artifact files.

use super::artifacts::{ArtifactSpec, SignatureDef};
use super::pjrt::{CompiledModel, OutTensor, XlaRuntime};
use crate::base::loader::{Loader, ResourceEstimate};
use crate::base::servable::ServableBox;
use crate::base::tensor::{Tensor, TensorI32};
use crate::batching::padding::pad_to_allowed;
use crate::lifecycle::source_adapter::FnSourceAdapter;
use crate::util::pool::BufferPool;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

/// How a servable executes a batch.
enum Engine {
    /// AOT-compiled executables on the batch-size ladder.
    Compiled(BTreeMap<usize, CompiledModel>),
    /// Deterministic pure-Rust model (tests/benches; no backend).
    Synthetic,
}

/// A loaded HLO model: fixed-shape executables on the batch-size ladder.
pub struct HloServable {
    pub spec: ArtifactSpec,
    engine: Engine,
    /// Device invocations ([`HloServable::run`] calls). With
    /// cross-request batching live, this is the denominator of the
    /// merge ratio: N concurrent requests should complete in ≪ N
    /// executions (what `tests/serving_concurrency.rs` pins).
    executions: std::sync::atomic::AtomicU64,
}

impl HloServable {
    /// Compile every ladder executable from a version directory.
    pub fn load(runtime: &Arc<XlaRuntime>, version_dir: &PathBuf) -> Result<HloServable> {
        let spec = ArtifactSpec::load(version_dir)?;
        if spec.platform != "hlo" {
            bail!("{}: platform '{}' is not hlo", version_dir.display(), spec.platform);
        }
        // A spec whose artifact pattern is the "synthetic" sentinel
        // (written by [`ArtifactSpec::write_to`]) carries no compiled
        // files: it loads as the synthetic engine. This lets the full
        // aspired-versions chain — FileSystemSource scan → loader →
        // load — run in builds without the PJRT backend, which is how
        // the TFS² control plane materializes servables onto replicas.
        if spec.artifact_pattern == "synthetic" {
            return Ok(HloServable::synthetic(spec));
        }
        let mut execs = BTreeMap::new();
        for &b in &spec.allowed_batch_sizes {
            let path = spec.artifact_path(version_dir, b);
            execs.insert(b, runtime.compile_hlo_file(&path)?);
        }
        Ok(HloServable {
            spec,
            engine: Engine::Compiled(execs),
            executions: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// A servable backed by the synthetic engine: same spec/signature
    /// contract, no compiled artifacts required.
    pub fn synthetic(spec: ArtifactSpec) -> HloServable {
        HloServable {
            spec,
            engine: Engine::Synthetic,
            executions: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// How many times [`HloServable::run`] has executed a batch.
    pub fn executions(&self) -> u64 {
        self.executions.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// The servable's named signatures (what `GetModelMetadata`
    /// reports).
    pub fn signatures(&self) -> &BTreeMap<String, SignatureDef> {
        &self.spec.signatures
    }

    /// Run a batch: pads the batch dimension up to the nearest compiled
    /// size, executes, and un-pads the outputs.
    ///
    /// Ladder-sized inputs (what [`crate::batching::session`] always
    /// delivers) run with **zero** copies here: no pad materializes and
    /// the un-padded outputs are O(1) views of the device buffers. Off-
    /// ladder inputs pad once through the global buffer pool, and the
    /// padded buffer recycles as soon as the executable is done with it.
    pub fn run(&self, input: &Tensor) -> Result<Vec<OutTensor>> {
        use crate::base::error::ErrorKind;
        // Chaos seam: an armed `exec:{model}` point injects a device
        // failure or latency spike here (no-op single atomic load when
        // nothing is armed). Consulted before the executions counter so
        // an injected *failure* doesn't count as an execution.
        crate::util::fault::hit(&format!("exec:{}", self.spec.model_name))?;
        // Version-scoped sibling: `exec:{model}@v{version}` faults one
        // version only — how rollout tests break a canary while the
        // stable version keeps serving from the same process.
        crate::util::fault::hit(&format!(
            "exec:{}@v{}",
            self.spec.model_name, self.spec.version
        ))?;
        self.executions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let rows = input.batch();
        if input.rank() != 2 || input.shape()[1] != self.spec.input_dim {
            // Request-caused: the gateway should answer 400, not 500.
            return Err(ErrorKind::InvalidArgument.err(format!(
                "{}: input shape {:?}, want [*, {}]",
                self.spec.model_name,
                input.shape(),
                self.spec.input_dim
            )));
        }
        let execs = match &self.engine {
            Engine::Synthetic => {
                // Contract parity with the compiled engine: batches
                // beyond the ladder are rejected, not silently served.
                let ladder = &self.spec.allowed_batch_sizes;
                if pad_to_allowed(rows, ladder).is_none() {
                    return Err(ErrorKind::InvalidArgument
                        .err(format!("batch {rows} exceeds compiled ladder {ladder:?}")));
                }
                return self.run_synthetic(input);
            }
            Engine::Compiled(execs) => execs,
        };
        let ladder: Vec<usize> = execs.keys().copied().collect();
        let target = pad_to_allowed(rows, &ladder).ok_or_else(|| {
            ErrorKind::InvalidArgument
                .err(format!("batch {rows} exceeds compiled ladder {ladder:?}"))
        })?;
        let outputs = if target == rows {
            execs[&target].run(input)?
        } else {
            let padded = input.pad_batch(target)?;
            let run = execs[&target].run(&padded);
            // Recycle the pad buffer on the error path too.
            padded.recycle_into(&BufferPool::global());
            run?
        };
        outputs.into_iter().map(|o| o.truncate_batch(rows)).collect()
    }

    /// The synthetic model: one deterministic output tensor per spec
    /// output, built through the buffer pools (f32 and i32 alike).
    ///
    /// * f32 rank-2 `[-1, C]` → row-wise log-softmax of per-class
    ///   scores (a valid distribution, version-dependent),
    /// * s32 rank-1 `[-1]` → argmax class of those scores,
    /// * f32 rank-1 `[-1]` → a regression value per row.
    fn run_synthetic(&self, input: &Tensor) -> Result<Vec<OutTensor>> {
        let rows = input.batch();
        let dim = self.spec.input_dim;
        let ver = self.spec.version as f32;
        let classes = self
            .spec
            .outputs
            .iter()
            .find(|o| o.dtype == "f32" && o.shape.len() == 2 && o.shape[1] > 0)
            .map(|o| o.shape[1] as usize)
            .unwrap_or(2);
        let score = |row: &[f32], c: usize| -> f32 {
            row.iter()
                .enumerate()
                .map(|(j, x)| x * (((j + 7 * c) as f32 + ver) * 0.37).sin())
                .sum()
        };
        // One [rows, classes] score pass shared by the log-probs and
        // argmax outputs, computed only when an output needs it.
        let needs_scores = self.spec.outputs.iter().any(|o| {
            (o.dtype == "f32" && o.shape.len() == 2) || (o.dtype == "s32" && o.shape.len() == 1)
        });
        let mut scores = Vec::new();
        if needs_scores {
            scores.reserve(rows * classes);
            for i in 0..rows {
                let row = input.row(i);
                for c in 0..classes {
                    scores.push(score(row, c));
                }
            }
        }
        let mut outs = Vec::with_capacity(self.spec.outputs.len());
        for info in &self.spec.outputs {
            let out = match (info.dtype.as_str(), info.shape.len()) {
                ("f32", 2) => OutTensor::F32(Tensor::build_with(
                    vec![rows, classes],
                    &BufferPool::global(),
                    |buf| {
                        for i in 0..rows {
                            let src = &scores[i * classes..(i + 1) * classes];
                            let dst = &mut buf[i * classes..(i + 1) * classes];
                            dst.copy_from_slice(src);
                            // log-softmax for a valid distribution
                            let max = dst.iter().copied().fold(f32::MIN, f32::max);
                            let lse =
                                dst.iter().map(|s| (s - max).exp()).sum::<f32>().ln() + max;
                            for d in dst.iter_mut() {
                                *d -= lse;
                            }
                        }
                    },
                )),
                ("s32", 1) => OutTensor::I32(TensorI32::build_with(
                    vec![rows],
                    &BufferPool::global_i32(),
                    |buf| {
                        for (i, b) in buf.iter_mut().enumerate() {
                            let row = &scores[i * classes..(i + 1) * classes];
                            *b = row
                                .iter()
                                .enumerate()
                                .max_by(|a, b| a.1.total_cmp(b.1))
                                .map(|(c, _)| c)
                                .unwrap_or(0) as i32;
                        }
                    },
                )),
                ("f32", 1) => OutTensor::F32(Tensor::build_with(
                    vec![rows],
                    &BufferPool::global(),
                    |buf| {
                        for (i, b) in buf.iter_mut().enumerate() {
                            let row = input.row(i);
                            *b = row.iter().sum::<f32>() / dim as f32 + 0.5 * ver;
                        }
                    },
                )),
                (dt, rank) => bail!(
                    "{}: synthetic engine cannot produce output '{}' ({dt}, rank {rank})",
                    self.spec.model_name,
                    info.name
                ),
            };
            outs.push(out);
        }
        Ok(outs)
    }

    pub fn allowed_batch_sizes(&self) -> Vec<usize> {
        match &self.engine {
            Engine::Compiled(execs) => execs.keys().copied().collect(),
            Engine::Synthetic => self.spec.allowed_batch_sizes.clone(),
        }
    }
}

/// Loads one HLO model version from a directory.
pub struct HloLoader {
    runtime: Arc<XlaRuntime>,
    version_dir: PathBuf,
}

impl HloLoader {
    pub fn new(runtime: Arc<XlaRuntime>, version_dir: PathBuf) -> Self {
        HloLoader { runtime, version_dir }
    }
}

impl Loader for HloLoader {
    fn estimate(&self) -> Result<ResourceEstimate> {
        // Pre-load estimate straight from the spec sidecar (what the
        // TFS² Controller bin-packs on).
        let spec = ArtifactSpec::load(&self.version_dir)?;
        Ok(ResourceEstimate::ram(spec.ram_estimate_bytes))
    }

    fn load(&self) -> Result<ServableBox> {
        let servable = HloServable::load(&self.runtime, &self.version_dir)?;
        Ok(Arc::new(servable) as ServableBox)
    }

    fn describe(&self) -> String {
        format!("hlo:{}", self.version_dir.display())
    }
}

/// Loader producing a synthetic servable from an in-memory spec (the
/// no-backend counterpart of [`HloLoader`]).
pub fn synthetic_loader(spec: ArtifactSpec) -> Arc<dyn Loader> {
    let describe = format!("synthetic:{}:{}", spec.model_name, spec.version);
    Arc::new(crate::base::loader::FnLoader::new(
        ResourceEstimate::ram(spec.ram_estimate_bytes),
        &describe,
        move || {
            // Chaos seam: an armed `load:{model}` point makes this load
            // attempt fail (transiently, if armed with a finite count) —
            // how chaos tests exercise the lifecycle's load retry.
            crate::util::fault::hit(&format!("load:{}", spec.model_name))?;
            Ok(Arc::new(HloServable::synthetic(spec.clone())) as ServableBox)
        },
    ))
}

/// The HLO platform's Source Adapter: storage path → [`HloLoader`]
/// (§2.1: "A TensorFlow Source Adapter converts each file path string
/// to a TensorFlow model Loader").
pub fn hlo_source_adapter(
    runtime: Arc<XlaRuntime>,
) -> Arc<FnSourceAdapter<PathBuf, Arc<dyn Loader>>> {
    FnSourceAdapter::new(move |data: &crate::base::aspired::ServableData<PathBuf>| {
        let dir = data.payload.as_ref().unwrap().clone();
        Ok(Arc::new(HloLoader::new(Arc::clone(&runtime), dir)) as Arc<dyn Loader>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};

    fn classifier_dir(version: u64) -> PathBuf {
        default_artifacts_root().join("mlp_classifier").join(version.to_string())
    }

    fn load_classifier() -> Option<HloServable> {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        let rt = XlaRuntime::shared().unwrap();
        Some(HloServable::load(&rt, &classifier_dir(2)).unwrap())
    }

    #[test]
    fn load_and_run_real_classifier() {
        let Some(servable) = load_classifier() else { return };
        assert_eq!(servable.spec.signature, "classify");
        assert_eq!(servable.allowed_batch_sizes(), vec![1, 4, 16, 64]);
        let input = Tensor::zeros(vec![3, 32]);
        let out = servable.run(&input).unwrap();
        // (log_probs, class)
        assert_eq!(out.len(), 2);
        let log_probs = out[0].as_f32().unwrap();
        let class = out[1].as_i32().unwrap();
        assert_eq!(log_probs.shape(), &[3, 4]);
        assert_eq!(class.shape(), &[3]);
        // log-probs exponentiate to a distribution
        for r in 0..3 {
            let s: f32 = log_probs.row(r).iter().map(|x| x.exp()).sum();
            assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
        }
    }

    #[test]
    fn padding_under_the_hood_matches_exact_batch() {
        let Some(servable) = load_classifier() else { return };
        // batch 3 runs on the b=4 executable; results for the 3 real
        // rows must match running them at exact ladder size b=1.
        let mut rows = Vec::new();
        for i in 0..3 {
            let row: Vec<f32> = (0..32).map(|j| ((i * 7 + j) as f32).sin()).collect();
            rows.push(row);
        }
        let batched = servable
            .run(&Tensor::matrix(rows.clone()).unwrap())
            .unwrap();
        for (i, row) in rows.into_iter().enumerate() {
            let single = servable.run(&Tensor::matrix(vec![row]).unwrap()).unwrap();
            let want = single[0].as_f32().unwrap().row(0);
            let got = batched[0].as_f32().unwrap();
            for (a, b) in want.iter().zip(got.row(i)) {
                assert!((a - b).abs() < 1e-5, "row {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let Some(servable) = load_classifier() else { return };
        assert!(servable.run(&Tensor::zeros(vec![2, 7])).is_err());
        assert!(servable.run(&Tensor::zeros(vec![65, 32])).is_err()); // over ladder
    }

    #[test]
    fn loader_estimate_before_load() {
        if !artifacts_available() {
            return;
        }
        let rt = XlaRuntime::shared().unwrap();
        let loader = HloLoader::new(rt, classifier_dir(1));
        let est = loader.estimate().unwrap();
        assert!(est.ram_bytes > 0);
        assert!(loader.describe().contains("mlp_classifier/1"));
    }

    #[test]
    fn v2_beats_v1_on_blob_like_data() {
        // The canary premise end-to-end: v2 (300 steps) should classify
        // more consistently than v1 (5 steps). We can't recreate the
        // training blobs exactly here, but both versions must at least
        // run and produce valid distributions.
        let Some(_) = load_classifier() else { return };
        let rt = XlaRuntime::shared().unwrap();
        let v1 = HloServable::load(&rt, &classifier_dir(1)).unwrap();
        let v2 = HloServable::load(&rt, &classifier_dir(2)).unwrap();
        let a1 = v1.spec.metrics.get("train_accuracy").unwrap().as_f64().unwrap();
        let a2 = v2.spec.metrics.get("train_accuracy").unwrap().as_f64().unwrap();
        assert!(a2 >= a1, "v2 acc {a2} < v1 acc {a1}");
    }

    // ----------------------------------------------- synthetic engine

    #[test]
    fn synthetic_classifier_runs_without_backend() {
        let servable =
            HloServable::synthetic(ArtifactSpec::synthetic_classifier("syn", 1, 8, 3));
        let input = Tensor::matrix(vec![
            (0..8).map(|j| (j as f32 * 0.3).sin()).collect(),
            (0..8).map(|j| (j as f32 * 0.9).cos()).collect(),
        ])
        .unwrap();
        let out = servable.run(&input).unwrap();
        assert_eq!(out.len(), 2);
        let log_probs = out[0].as_f32().unwrap();
        let class = out[1].as_i32().unwrap();
        assert_eq!(log_probs.shape(), &[2, 3]);
        assert_eq!(class.shape(), &[2]);
        for i in 0..2 {
            let p: f32 = log_probs.row(i).iter().map(|x| x.exp()).sum();
            assert!((p - 1.0).abs() < 1e-4, "row {i} sums to {p}");
            let argmax = log_probs
                .row(i)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0 as i32;
            assert_eq!(class.data()[i], argmax);
        }
        // Deterministic across calls.
        let again = servable.run(&input).unwrap();
        assert_eq!(again[0].as_f32().unwrap(), log_probs);
        // Wrong input dim still rejected, and so are batches beyond
        // the ladder — contract parity with the compiled engine.
        assert!(servable.run(&Tensor::zeros(vec![1, 5])).is_err());
        let over = servable.spec.max_batch_size() + 1;
        assert!(servable.run(&Tensor::zeros(vec![over, 8])).is_err());
    }

    #[test]
    fn synthetic_versions_differ() {
        let v1 = HloServable::synthetic(ArtifactSpec::synthetic_classifier("s", 1, 8, 3));
        let v2 = HloServable::synthetic(ArtifactSpec::synthetic_classifier("s", 2, 8, 3));
        let input = Tensor::matrix(vec![(0..8).map(|j| j as f32).collect()]).unwrap();
        let o1 = v1.run(&input).unwrap();
        let o2 = v2.run(&input).unwrap();
        assert_ne!(o1[0].as_f32().unwrap(), o2[0].as_f32().unwrap());
    }

    #[test]
    fn exec_fault_point_injects_then_recovers() {
        use crate::util::fault::{arm, Fault};
        // Unique model name: the fault registry is process-global.
        let servable = HloServable::synthetic(ArtifactSpec::synthetic_classifier(
            "fault_exec_syn",
            1,
            8,
            3,
        ));
        arm("exec:fault_exec_syn", Fault::Fail { message: "chaos".into() }, 1);
        let input = Tensor::zeros(vec![1, 8]);
        let e = servable.run(&input).unwrap_err();
        assert!(e.to_string().contains("chaos"), "{e}");
        // An injected failure is not an execution.
        assert_eq!(servable.executions(), 0);
        // Charge spent: the next run succeeds.
        assert_eq!(servable.run(&input).unwrap().len(), 2);
        assert_eq!(servable.executions(), 1);
    }

    #[test]
    fn synthetic_spec_on_disk_loads_without_backend() {
        // write_to → HloServable::load: the "synthetic" artifact
        // pattern short-circuits compilation, so the whole file-system
        // source chain works with no PJRT backend and no HLO files.
        let spec = ArtifactSpec::synthetic_multi_head("disk_syn", 3, 8, 3);
        let dir = std::env::temp_dir()
            .join(format!("ts-hlo-disk-syn-{}", std::process::id()))
            .join("disk_syn")
            .join("3");
        spec.write_to(&dir).unwrap();
        let rt = XlaRuntime::shared().unwrap();
        let servable = HloServable::load(&rt, &dir).unwrap();
        assert_eq!(servable.spec, spec);
        let out = servable.run(&Tensor::zeros(vec![2, 8])).unwrap();
        assert_eq!(out.len(), 3);
        // The loader's pre-load estimate reads the same sidecar.
        let est = HloLoader::new(rt, dir.clone()).estimate().unwrap();
        assert_eq!(est.ram_bytes, spec.ram_estimate_bytes);
        std::fs::remove_dir_all(dir.parent().unwrap().parent().unwrap()).ok();
    }

    #[test]
    fn synthetic_multi_head_produces_all_outputs() {
        let servable =
            HloServable::synthetic(ArtifactSpec::synthetic_multi_head("syn", 2, 8, 3));
        let out = servable.run(&Tensor::zeros(vec![4, 8])).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].as_f32().unwrap().shape(), &[4, 3]);
        assert_eq!(out[1].as_i32().unwrap().shape(), &[4]);
        assert_eq!(out[2].as_f32().unwrap().shape(), &[4]);
        assert!(servable.signatures().contains_key("regress"));
    }
}
