//! [`HloServable`]: the "TensorFlow platform" of this reproduction —
//! one compiled executable per allowed batch size plus its spec — and
//! the [`HloLoader`]/[`hlo_source_adapter`] that plug it into the
//! lifecycle chain (§2.1's TensorFlow Source Adapter analogue).

use super::artifacts::ModelSpec;
use super::pjrt::{CompiledModel, OutTensor, XlaRuntime};
use crate::base::loader::{Loader, ResourceEstimate};
use crate::base::servable::ServableBox;
use crate::base::tensor::Tensor;
use crate::batching::padding::pad_to_allowed;
use crate::lifecycle::source_adapter::FnSourceAdapter;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

/// A loaded HLO model: fixed-shape executables on the batch-size ladder.
pub struct HloServable {
    pub spec: ModelSpec,
    execs: BTreeMap<usize, CompiledModel>,
}

impl HloServable {
    /// Compile every ladder executable from a version directory.
    pub fn load(runtime: &Arc<XlaRuntime>, version_dir: &PathBuf) -> Result<HloServable> {
        let spec = ModelSpec::load(version_dir)?;
        if spec.platform != "hlo" {
            bail!("{}: platform '{}' is not hlo", version_dir.display(), spec.platform);
        }
        let mut execs = BTreeMap::new();
        for &b in &spec.allowed_batch_sizes {
            let path = spec.artifact_path(version_dir, b);
            execs.insert(b, runtime.compile_hlo_file(&path)?);
        }
        Ok(HloServable { spec, execs })
    }

    /// Run a batch: pads the batch dimension up to the nearest compiled
    /// size, executes, and un-pads the outputs.
    ///
    /// Ladder-sized inputs (what [`crate::batching::session`] always
    /// delivers) run with **zero** copies here: no pad materializes and
    /// the un-padded outputs are O(1) views of the device buffers. Off-
    /// ladder inputs pad once through the global buffer pool, and the
    /// padded buffer recycles as soon as the executable is done with it.
    pub fn run(&self, input: &Tensor) -> Result<Vec<OutTensor>> {
        let rows = input.batch();
        if input.rank() != 2 || input.shape()[1] != self.spec.input_dim {
            bail!(
                "{}: input shape {:?}, want [*, {}]",
                self.spec.model_name,
                input.shape(),
                self.spec.input_dim
            );
        }
        let ladder: Vec<usize> = self.execs.keys().copied().collect();
        let target = pad_to_allowed(rows, &ladder)
            .ok_or_else(|| anyhow!("batch {rows} exceeds compiled ladder {ladder:?}"))?;
        let outputs = if target == rows {
            self.execs[&target].run(input)?
        } else {
            let padded = input.pad_batch(target)?;
            let outputs = self.execs[&target].run(&padded)?;
            padded.recycle_into(&crate::util::pool::BufferPool::global());
            outputs
        };
        outputs.into_iter().map(|o| o.truncate_batch(rows)).collect()
    }

    pub fn allowed_batch_sizes(&self) -> Vec<usize> {
        self.execs.keys().copied().collect()
    }
}

/// Loads one HLO model version from a directory.
pub struct HloLoader {
    runtime: Arc<XlaRuntime>,
    version_dir: PathBuf,
}

impl HloLoader {
    pub fn new(runtime: Arc<XlaRuntime>, version_dir: PathBuf) -> Self {
        HloLoader { runtime, version_dir }
    }
}

impl Loader for HloLoader {
    fn estimate(&self) -> Result<ResourceEstimate> {
        // Pre-load estimate straight from the spec sidecar (what the
        // TFS² Controller bin-packs on).
        let spec = ModelSpec::load(&self.version_dir)?;
        Ok(ResourceEstimate::ram(spec.ram_estimate_bytes))
    }

    fn load(&self) -> Result<ServableBox> {
        let servable = HloServable::load(&self.runtime, &self.version_dir)?;
        Ok(Arc::new(servable) as ServableBox)
    }

    fn describe(&self) -> String {
        format!("hlo:{}", self.version_dir.display())
    }
}

/// The HLO platform's Source Adapter: storage path → [`HloLoader`]
/// (§2.1: "A TensorFlow Source Adapter converts each file path string
/// to a TensorFlow model Loader").
pub fn hlo_source_adapter(
    runtime: Arc<XlaRuntime>,
) -> Arc<FnSourceAdapter<PathBuf, Arc<dyn Loader>>> {
    FnSourceAdapter::new(move |data: &crate::base::aspired::ServableData<PathBuf>| {
        let dir = data.payload.as_ref().unwrap().clone();
        Ok(Arc::new(HloLoader::new(Arc::clone(&runtime), dir)) as Arc<dyn Loader>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};

    fn classifier_dir(version: u64) -> PathBuf {
        default_artifacts_root().join("mlp_classifier").join(version.to_string())
    }

    fn load_classifier() -> Option<HloServable> {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        let rt = XlaRuntime::shared().unwrap();
        Some(HloServable::load(&rt, &classifier_dir(2)).unwrap())
    }

    #[test]
    fn load_and_run_real_classifier() {
        let Some(servable) = load_classifier() else { return };
        assert_eq!(servable.spec.signature, "classify");
        assert_eq!(servable.allowed_batch_sizes(), vec![1, 4, 16, 64]);
        let input = Tensor::zeros(vec![3, 32]);
        let out = servable.run(&input).unwrap();
        // (log_probs, class)
        assert_eq!(out.len(), 2);
        let log_probs = out[0].as_f32().unwrap();
        let class = out[1].as_i32().unwrap();
        assert_eq!(log_probs.shape(), &[3, 4]);
        assert_eq!(class.shape(), &[3]);
        // log-probs exponentiate to a distribution
        for r in 0..3 {
            let s: f32 = log_probs.row(r).iter().map(|x| x.exp()).sum();
            assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
        }
    }

    #[test]
    fn padding_under_the_hood_matches_exact_batch() {
        let Some(servable) = load_classifier() else { return };
        // batch 3 runs on the b=4 executable; results for the 3 real
        // rows must match running them at exact ladder size b=1.
        let mut rows = Vec::new();
        for i in 0..3 {
            let row: Vec<f32> = (0..32).map(|j| ((i * 7 + j) as f32).sin()).collect();
            rows.push(row);
        }
        let batched = servable
            .run(&Tensor::matrix(rows.clone()).unwrap())
            .unwrap();
        for (i, row) in rows.into_iter().enumerate() {
            let single = servable.run(&Tensor::matrix(vec![row]).unwrap()).unwrap();
            let want = single[0].as_f32().unwrap().row(0);
            let got = batched[0].as_f32().unwrap();
            for (a, b) in want.iter().zip(got.row(i)) {
                assert!((a - b).abs() < 1e-5, "row {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let Some(servable) = load_classifier() else { return };
        assert!(servable.run(&Tensor::zeros(vec![2, 7])).is_err());
        assert!(servable.run(&Tensor::zeros(vec![65, 32])).is_err()); // over ladder
    }

    #[test]
    fn loader_estimate_before_load() {
        if !artifacts_available() {
            return;
        }
        let rt = XlaRuntime::shared().unwrap();
        let loader = HloLoader::new(rt, classifier_dir(1));
        let est = loader.estimate().unwrap();
        assert!(est.ram_bytes > 0);
        assert!(loader.describe().contains("mlp_classifier/1"));
    }

    #[test]
    fn v2_beats_v1_on_blob_like_data() {
        // The canary premise end-to-end: v2 (300 steps) should classify
        // more consistently than v1 (5 steps). We can't recreate the
        // training blobs exactly here, but both versions must at least
        // run and produce valid distributions.
        let Some(_) = load_classifier() else { return };
        let rt = XlaRuntime::shared().unwrap();
        let v1 = HloServable::load(&rt, &classifier_dir(1)).unwrap();
        let v2 = HloServable::load(&rt, &classifier_dir(2)).unwrap();
        let a1 = v1.spec.metrics.get("train_accuracy").unwrap().as_f64().unwrap();
        let a2 = v2.spec.metrics.get("train_accuracy").unwrap().as_f64().unwrap();
        assert!(a2 >= a1, "v2 acc {a2} < v1 acc {a1}");
    }
}
