//! Model runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! This plays the role TensorFlow's `Session::Run()` plays in the paper:
//! the opaque executable behind a servable. Artifacts are HLO *text*
//! emitted by `python/compile/aot.py` (HLO text is the interchange
//! format because the bundled xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit-id serialized protos); [`pjrt`] compiles them on the PJRT CPU
//! client, [`artifacts`] reads the `spec.json` sidecars, and
//! [`hlo_servable`] packages one executable per allowed batch size into
//! the servable the manager hands out.

pub mod artifacts;
pub mod hlo_servable;
pub mod pjrt;
