//! Artifact layout, `spec.json` sidecars (the contract with
//! `python/compile/aot.py`), and the signature metadata derived from
//! them.
//!
//! A servable's callable surface is described by named
//! [`SignatureDef`]s (the paper's signature-addressed inference): each
//! maps a method ("predict" / "classify" / "regress") to named, typed,
//! shaped input and output tensors. Specs that don't declare a
//! `signatures` object get a default serving signature synthesized
//! from their top-level input/outputs, so every existing artifact is
//! addressable as `"serving_default"`.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// The signature name every servable answers to when the client does
/// not name one.
pub const DEFAULT_SIGNATURE: &str = "serving_default";

/// Name + dtype + shape of one signature input or output tensor
/// (`-1` = dynamic dimension, in practice the batch dimension).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorInfo {
    pub name: String,
    /// "f32" or "s32".
    pub dtype: String,
    pub shape: Vec<i64>,
}

impl TensorInfo {
    pub fn f32(name: &str, shape: Vec<i64>) -> TensorInfo {
        TensorInfo { name: name.to_string(), dtype: "f32".into(), shape }
    }

    pub fn s32(name: &str, shape: Vec<i64>) -> TensorInfo {
        TensorInfo { name: name.to_string(), dtype: "s32".into(), shape }
    }

    /// True if a concrete tensor shape is compatible: same rank, and
    /// every non-dynamic declared dim matches.
    pub fn matches_shape(&self, shape: &[usize]) -> bool {
        self.shape.len() == shape.len()
            && self
                .shape
                .iter()
                .zip(shape)
                .all(|(&want, &got)| want < 0 || want as usize == got)
    }
}

/// One named way to call a servable: a method plus its typed tensors.
#[derive(Debug, Clone, PartialEq)]
pub struct SignatureDef {
    /// "predict" | "classify" | "regress".
    pub method: String,
    pub inputs: Vec<TensorInfo>,
    /// Subset (often all) of the executable's outputs, by name.
    pub outputs: Vec<TensorInfo>,
}

/// Parsed `spec.json` for one model version.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub platform: String,
    /// Default method ("classify" | "regress" | "predict") — the
    /// method of the default serving signature.
    pub signature: String,
    pub model_name: String,
    pub version: u64,
    /// The executable's single input.
    pub input: TensorInfo,
    pub input_dim: usize,
    /// The executable's outputs, in tuple order.
    pub outputs: Vec<TensorInfo>,
    /// Named signatures clients can address. Always contains
    /// [`DEFAULT_SIGNATURE`].
    pub signatures: BTreeMap<String, SignatureDef>,
    pub allowed_batch_sizes: Vec<usize>,
    pub artifact_pattern: String,
    pub ram_estimate_bytes: u64,
    pub n_params: u64,
    /// Training metrics (accuracy/mse), for canary comparisons.
    pub metrics: Json,
}

impl ArtifactSpec {
    pub fn parse(json: &Json, origin: &str) -> Result<ArtifactSpec> {
        let get_str = |k: &str| -> Result<String> {
            Ok(json
                .get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("{origin}: missing string '{k}'"))?
                .to_string())
        };
        let input_dims: Vec<i64> = json
            .get_path("input.shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("{origin}: bad input.shape"))?
            .iter()
            .map(|d| d.as_i64())
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("{origin}: non-integer input.shape dim"))?;
        let input_dim = *input_dims
            .last()
            .ok_or_else(|| anyhow!("{origin}: empty input.shape"))? as usize;
        // Declared shape, batch dim dynamic — preserved at full rank,
        // not collapsed to [-1, input_dim].
        let mut input_shape = input_dims;
        input_shape[0] = -1;
        let input = TensorInfo {
            name: json
                .get_path("input.name")
                .and_then(|v| v.as_str())
                .unwrap_or("x")
                .to_string(),
            dtype: json
                .get_path("input.dtype")
                .and_then(|v| v.as_str())
                .unwrap_or("f32")
                .to_string(),
            shape: input_shape,
        };
        let outputs = json
            .get("outputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("{origin}: missing outputs"))?
            .iter()
            .map(|o| {
                let name = o
                    .get("name")
                    .and_then(|n| n.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("{origin}: output without name"))?;
                let dtype = o
                    .get("dtype")
                    .and_then(|v| v.as_str())
                    .unwrap_or("f32")
                    .to_string();
                let shape = match o.get("shape") {
                    None => vec![-1],
                    Some(v) => v
                        .as_arr()
                        .ok_or_else(|| anyhow!("{origin}: output '{name}': bad shape"))?
                        .iter()
                        .map(|d| d.as_i64())
                        .collect::<Option<Vec<_>>>()
                        .ok_or_else(|| {
                            anyhow!("{origin}: output '{name}': non-integer shape dim")
                        })?,
                };
                Ok(TensorInfo { name, dtype, shape })
            })
            .collect::<Result<Vec<_>>>()?;
        let allowed_batch_sizes: Vec<usize> = json
            .get("allowed_batch_sizes")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("{origin}: missing allowed_batch_sizes"))?
            .iter()
            .map(|v| v.as_u64().map(|x| x as usize))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("{origin}: bad allowed_batch_sizes"))?;
        if allowed_batch_sizes.is_empty() {
            bail!("{origin}: empty allowed_batch_sizes");
        }
        let signature = get_str("signature")?;
        let mut signatures =
            parse_signatures(json.get("signatures"), &input, &outputs, origin)?;
        ensure_default_signatures(&mut signatures, &signature, &input, &outputs);
        Ok(ArtifactSpec {
            platform: get_str("platform")?,
            signature,
            model_name: get_str("model_name")?,
            version: json
                .get("version")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("{origin}: missing version"))?,
            input,
            input_dim,
            outputs,
            signatures,
            allowed_batch_sizes,
            artifact_pattern: get_str("artifact_pattern")?,
            ram_estimate_bytes: json
                .get("ram_estimate_bytes")
                .and_then(|v| v.as_u64())
                .unwrap_or(0),
            n_params: json.get("n_params").and_then(|v| v.as_u64()).unwrap_or(0),
            metrics: json.get("metrics").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn load(version_dir: &Path) -> Result<ArtifactSpec> {
        let path = version_dir.join("spec.json");
        let json = Json::parse_file(&path).context("loading spec")?;
        Self::parse(&json, &path.display().to_string())
    }

    /// HLO file for a given compiled batch size.
    pub fn artifact_path(&self, version_dir: &Path, batch: usize) -> PathBuf {
        version_dir.join(self.artifact_pattern.replace("{batch}", &batch.to_string()))
    }

    pub fn max_batch_size(&self) -> usize {
        *self.allowed_batch_sizes.last().unwrap()
    }

    pub fn output_names(&self) -> Vec<&str> {
        self.outputs.iter().map(|o| o.name.as_str()).collect()
    }

    /// Position of a named output in the executable's output tuple.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|o| o.name == name)
    }

    /// Look up a signature by name (empty = [`DEFAULT_SIGNATURE`]),
    /// with an error that lists what is available.
    pub fn signature_def(&self, name: &str) -> Result<(&str, &SignatureDef)> {
        let want = if name.is_empty() { DEFAULT_SIGNATURE } else { name };
        match self.signatures.get_key_value(want) {
            Some((k, v)) => Ok((k.as_str(), v)),
            None => Err(crate::base::error::ErrorKind::InvalidArgument.err(format!(
                "model '{}' has no signature '{}' (available: {:?})",
                self.model_name,
                want,
                self.signatures.keys().collect::<Vec<_>>()
            ))),
        }
    }

    /// In-memory spec for a synthetic servable (no artifact files, no
    /// PJRT backend): one classify signature over `classes` classes.
    /// Used by tests/benches that exercise the full serving stack
    /// without compiled models.
    pub fn synthetic_classifier(
        name: &str,
        version: u64,
        input_dim: usize,
        classes: usize,
    ) -> ArtifactSpec {
        let input = TensorInfo::f32("x", vec![-1, input_dim as i64]);
        let outputs = vec![
            TensorInfo::f32("log_probs", vec![-1, classes as i64]),
            TensorInfo::s32("class", vec![-1]),
        ];
        let mut signatures = BTreeMap::new();
        ensure_default_signatures(&mut signatures, "classify", &input, &outputs);
        ArtifactSpec {
            platform: "hlo".into(),
            signature: "classify".into(),
            model_name: name.to_string(),
            version,
            input,
            input_dim,
            outputs,
            signatures,
            allowed_batch_sizes: vec![64],
            artifact_pattern: "synthetic".into(),
            ram_estimate_bytes: 1 << 16,
            n_params: 0,
            metrics: Json::Null,
        }
    }

    /// Serialize back to the `spec.json` schema [`ArtifactSpec::parse`]
    /// reads. Every signature is written explicitly (method + output
    /// names), so parsing the result reconstructs the identical
    /// signature map and `ensure_default_signatures` is a no-op.
    pub fn to_json(&self) -> Json {
        let tensor = |t: &TensorInfo| {
            Json::obj(vec![
                ("name", Json::str(t.name.clone())),
                ("dtype", Json::str(t.dtype.clone())),
                ("shape", Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect())),
            ])
        };
        let signatures = Json::Obj(
            self.signatures
                .iter()
                .map(|(name, def)| {
                    let outputs =
                        def.outputs.iter().map(|o| Json::str(o.name.clone())).collect();
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("method", Json::str(def.method.clone())),
                            ("outputs", Json::Arr(outputs)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("platform", Json::str(self.platform.clone())),
            ("signature", Json::str(self.signature.clone())),
            ("model_name", Json::str(self.model_name.clone())),
            ("version", Json::Num(self.version as f64)),
            ("input", tensor(&self.input)),
            ("outputs", Json::Arr(self.outputs.iter().map(tensor).collect())),
            ("signatures", signatures),
            (
                "allowed_batch_sizes",
                Json::Arr(self.allowed_batch_sizes.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("artifact_pattern", Json::str(self.artifact_pattern.clone())),
            ("ram_estimate_bytes", Json::Num(self.ram_estimate_bytes as f64)),
            ("n_params", Json::Num(self.n_params as f64)),
            ("metrics", self.metrics.clone()),
        ])
    }

    /// Write `spec.json` into `version_dir` (creating it) — the
    /// on-disk form [`ArtifactSpec::load`] reads back. How the control
    /// plane materializes synthetic servables under a file-system
    /// source's watch root.
    pub fn write_to(&self, version_dir: &Path) -> Result<()> {
        std::fs::create_dir_all(version_dir)
            .with_context(|| format!("creating {}", version_dir.display()))?;
        let path = version_dir.join("spec.json");
        std::fs::write(&path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Two-headed synthetic spec: a classify head (`log_probs`,
    /// `class`) and a regress head (`value`) over one shared input —
    /// the MultiInference test fixture.
    pub fn synthetic_multi_head(
        name: &str,
        version: u64,
        input_dim: usize,
        classes: usize,
    ) -> ArtifactSpec {
        let mut spec = Self::synthetic_classifier(name, version, input_dim, classes);
        spec.outputs.push(TensorInfo::f32("value", vec![-1]));
        spec.signatures.insert(
            "classify".into(),
            SignatureDef {
                method: "classify".into(),
                inputs: vec![spec.input.clone()],
                outputs: vec![spec.outputs[0].clone(), spec.outputs[1].clone()],
            },
        );
        spec.signatures.insert(
            "regress".into(),
            SignatureDef {
                method: "regress".into(),
                inputs: vec![spec.input.clone()],
                outputs: vec![spec.outputs[2].clone()],
            },
        );
        // serving_default keeps the classify heads only; the full
        // output tuple stays reachable through "predict_all".
        spec.signatures.insert(
            "predict_all".into(),
            SignatureDef {
                method: "predict".into(),
                inputs: vec![spec.input.clone()],
                outputs: spec.outputs.clone(),
            },
        );
        spec
    }
}

/// Parse an optional `signatures` JSON object:
/// `{"name": {"method": "classify", "outputs": ["log_probs","class"]}}`.
/// Output names must reference the executable's top-level outputs;
/// inputs are implicitly the model input.
fn parse_signatures(
    json: Option<&Json>,
    input: &TensorInfo,
    outputs: &[TensorInfo],
    origin: &str,
) -> Result<BTreeMap<String, SignatureDef>> {
    let mut map = BTreeMap::new();
    // Key absent is fine (defaults synthesize); key present but not an
    // object is a spec error, reported at load time not request time.
    let Some(json) = json else {
        return Ok(map);
    };
    let obj = json
        .as_obj()
        .ok_or_else(|| anyhow!("{origin}: 'signatures' must be an object"))?;
    for (name, def) in obj {
        let method = def
            .get("method")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("{origin}: signature '{name}' missing method"))?
            .to_string();
        let out_names = def
            .get("outputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("{origin}: signature '{name}' missing outputs"))?;
        let sig_outputs = out_names
            .iter()
            .map(|n| {
                let n = n
                    .as_str()
                    .ok_or_else(|| anyhow!("{origin}: signature '{name}': non-string output"))?;
                outputs
                    .iter()
                    .find(|o| o.name == n)
                    .cloned()
                    .ok_or_else(|| {
                        anyhow!("{origin}: signature '{name}' references unknown output '{n}'")
                    })
            })
            .collect::<Result<Vec<_>>>()?;
        map.insert(
            name.clone(),
            SignatureDef { method, inputs: vec![input.clone()], outputs: sig_outputs },
        );
    }
    Ok(map)
}

/// Guarantee [`DEFAULT_SIGNATURE`] exists (full output tuple, the
/// spec's default method) and alias it under the method name so
/// `signature: "classify"` stays addressable as `"classify"`.
fn ensure_default_signatures(
    signatures: &mut BTreeMap<String, SignatureDef>,
    method: &str,
    input: &TensorInfo,
    outputs: &[TensorInfo],
) {
    let def = SignatureDef {
        method: method.to_string(),
        inputs: vec![input.clone()],
        outputs: outputs.to_vec(),
    };
    if !signatures.contains_key(DEFAULT_SIGNATURE) {
        signatures.insert(DEFAULT_SIGNATURE.into(), def.clone());
    }
    if !signatures.contains_key(method) {
        signatures.insert(method.to_string(), def);
    }
}

/// The artifacts root used by tests/examples: `$TS_ARTIFACTS` or
/// `<repo>/artifacts`.
pub fn default_artifacts_root() -> PathBuf {
    if let Ok(p) = std::env::var("TS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if `make artifacts` has produced the models examples need AND
/// this build can execute them. Without the `xla` feature the PJRT
/// backend is a stub, so artifact-driven tests/examples must skip even
/// when the files exist — loading would fail, not run.
pub fn artifacts_available() -> bool {
    cfg!(feature = "xla") && default_artifacts_root().join("mlp_classifier").is_dir()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"{
      "platform": "hlo", "signature": "classify",
      "model_name": "m", "version": 3,
      "input": {"name": "x", "shape": [-1, 32], "dtype": "f32"},
      "outputs": [{"name": "log_probs", "shape": [-1, 4], "dtype": "f32"},
                  {"name": "class", "shape": [-1], "dtype": "s32"}],
      "allowed_batch_sizes": [1, 4, 16],
      "artifact_pattern": "model_b{batch}.hlo.txt",
      "ram_estimate_bytes": 123456, "n_params": 999,
      "metrics": {"train_accuracy": 0.98}
    }"#;

    #[test]
    fn parse_full_spec() {
        let spec = ArtifactSpec::parse(&Json::parse(SPEC).unwrap(), "test").unwrap();
        assert_eq!(spec.model_name, "m");
        assert_eq!(spec.version, 3);
        assert_eq!(spec.input_dim, 32);
        assert_eq!(spec.input.name, "x");
        assert_eq!(spec.output_names(), vec!["log_probs", "class"]);
        assert_eq!(spec.outputs[1].dtype, "s32");
        assert_eq!(spec.allowed_batch_sizes, vec![1, 4, 16]);
        assert_eq!(spec.max_batch_size(), 16);
        assert_eq!(spec.ram_estimate_bytes, 123456);
        assert_eq!(
            spec.metrics.get("train_accuracy").unwrap().as_f64(),
            Some(0.98)
        );
    }

    #[test]
    fn default_signature_synthesized() {
        let spec = ArtifactSpec::parse(&Json::parse(SPEC).unwrap(), "test").unwrap();
        let (name, def) = spec.signature_def("").unwrap();
        assert_eq!(name, DEFAULT_SIGNATURE);
        assert_eq!(def.method, "classify");
        assert_eq!(def.inputs.len(), 1);
        assert_eq!(def.inputs[0].shape, vec![-1, 32]);
        assert_eq!(def.outputs.len(), 2);
        // Aliased under the method name too.
        let (_, alias) = spec.signature_def("classify").unwrap();
        assert_eq!(alias, def);
        // Unknown signatures error and list what exists.
        let err = spec.signature_def("nope").unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains("serving_default"), "{err}");
    }

    #[test]
    fn explicit_signatures_parsed_and_validated() {
        let with_sigs = SPEC.replace(
            "\"metrics\": {\"train_accuracy\": 0.98}",
            r#""metrics": {},
               "signatures": {"heads": {"method": "classify",
                                        "outputs": ["class"]}}"#,
        );
        let spec = ArtifactSpec::parse(&Json::parse(&with_sigs).unwrap(), "t").unwrap();
        let (_, heads) = spec.signature_def("heads").unwrap();
        assert_eq!(heads.outputs.len(), 1);
        assert_eq!(heads.outputs[0].name, "class");
        // serving_default still synthesized alongside.
        assert!(spec.signatures.contains_key(DEFAULT_SIGNATURE));

        let bad = with_sigs.replace("[\"class\"]", "[\"missing_output\"]");
        let err = ArtifactSpec::parse(&Json::parse(&bad).unwrap(), "t").unwrap_err();
        assert!(err.to_string().contains("missing_output"), "{err}");
    }

    #[test]
    fn malformed_output_dims_error_loudly() {
        // A non-integer dim must fail parse, not silently shrink rank.
        let bad = SPEC.replace(r#""shape": [-1, 4]"#, r#""shape": [-1, "4"]"#);
        let err = ArtifactSpec::parse(&Json::parse(&bad).unwrap(), "t").unwrap_err();
        assert!(err.to_string().contains("shape"), "{err}");
    }

    #[test]
    fn tensor_info_shape_matching() {
        let info = TensorInfo::f32("x", vec![-1, 32]);
        assert!(info.matches_shape(&[7, 32]));
        assert!(!info.matches_shape(&[7, 31]));
        assert!(!info.matches_shape(&[32]));
    }

    #[test]
    fn synthetic_specs_have_heads() {
        let spec = ArtifactSpec::synthetic_multi_head("syn", 2, 8, 3);
        assert_eq!(spec.output_index("value"), Some(2));
        let (_, c) = spec.signature_def("classify").unwrap();
        assert_eq!(c.method, "classify");
        let (_, r) = spec.signature_def("regress").unwrap();
        assert_eq!(r.method, "regress");
        assert_eq!(r.outputs[0].name, "value");
        let (_, d) = spec.signature_def("").unwrap();
        assert_eq!(d.method, "classify");
    }

    #[test]
    fn artifact_path_substitution() {
        let spec = ArtifactSpec::parse(&Json::parse(SPEC).unwrap(), "test").unwrap();
        assert_eq!(
            spec.artifact_path(Path::new("/a/b/3"), 16),
            PathBuf::from("/a/b/3/model_b16.hlo.txt")
        );
    }

    #[test]
    fn parse_rejects_incomplete() {
        let bad = Json::parse(r#"{"platform": "hlo"}"#).unwrap();
        assert!(ArtifactSpec::parse(&bad, "t").is_err());
        let no_sizes = Json::parse(
            r#"{"platform":"hlo","signature":"s","model_name":"m","version":1,
                "input":{"shape":[-1,4]},"outputs":[],"allowed_batch_sizes":[],
                "artifact_pattern":"x"}"#,
        )
        .unwrap();
        assert!(ArtifactSpec::parse(&no_sizes, "t").is_err());
    }

    #[test]
    fn spec_roundtrips_through_json_and_disk() {
        // to_json → parse must reconstruct the identical spec,
        // including the explicit multi-head signature map.
        let spec = ArtifactSpec::synthetic_multi_head("rt", 7, 8, 3);
        let back = ArtifactSpec::parse(&spec.to_json(), "roundtrip").unwrap();
        assert_eq!(back, spec);

        // write_to → load: the on-disk form the control plane emits.
        let dir = std::env::temp_dir()
            .join(format!("ts-artifacts-rt-{}", std::process::id()))
            .join("rt")
            .join("7");
        spec.write_to(&dir).unwrap();
        let loaded = ArtifactSpec::load(&dir).unwrap();
        assert_eq!(loaded, spec);
        std::fs::remove_dir_all(dir.parent().unwrap().parent().unwrap()).ok();
    }

    #[test]
    fn real_artifacts_parse_if_present() {
        let root = default_artifacts_root();
        if !artifacts_available() {
            return; // make artifacts not run yet
        }
        for model in ["mlp_classifier", "mlp_regressor"] {
            for v in [1u64, 2] {
                let dir = root.join(model).join(v.to_string());
                let spec = ArtifactSpec::load(&dir).unwrap();
                assert_eq!(spec.model_name, model);
                assert_eq!(spec.version, v);
                assert_eq!(spec.input_dim, 32);
                assert!(spec.signatures.contains_key(DEFAULT_SIGNATURE));
                for &b in &spec.allowed_batch_sizes {
                    assert!(spec.artifact_path(&dir, b).exists());
                }
            }
        }
    }
}
