//! Artifact layout and `spec.json` sidecars (the contract with
//! `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `spec.json` for one model version.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub platform: String,
    pub signature: String, // "classify" | "regress" | "predict"
    pub model_name: String,
    pub version: u64,
    pub input_dim: usize,
    pub output_names: Vec<String>,
    pub allowed_batch_sizes: Vec<usize>,
    pub artifact_pattern: String,
    pub ram_estimate_bytes: u64,
    pub n_params: u64,
    /// Training metrics (accuracy/mse), for canary comparisons.
    pub metrics: Json,
}

impl ModelSpec {
    pub fn parse(json: &Json, origin: &str) -> Result<ModelSpec> {
        let get_str = |k: &str| -> Result<String> {
            Ok(json
                .get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("{origin}: missing string '{k}'"))?
                .to_string())
        };
        let input_dim = json
            .get_path("input.shape")
            .and_then(|v| v.as_arr())
            .and_then(|a| a.last())
            .and_then(|v| v.as_i64())
            .ok_or_else(|| anyhow!("{origin}: bad input.shape"))? as usize;
        let output_names = json
            .get("outputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("{origin}: missing outputs"))?
            .iter()
            .map(|o| {
                o.get("name")
                    .and_then(|n| n.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("{origin}: output without name"))
            })
            .collect::<Result<Vec<_>>>()?;
        let allowed_batch_sizes: Vec<usize> = json
            .get("allowed_batch_sizes")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("{origin}: missing allowed_batch_sizes"))?
            .iter()
            .map(|v| v.as_u64().map(|x| x as usize))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("{origin}: bad allowed_batch_sizes"))?;
        if allowed_batch_sizes.is_empty() {
            bail!("{origin}: empty allowed_batch_sizes");
        }
        Ok(ModelSpec {
            platform: get_str("platform")?,
            signature: get_str("signature")?,
            model_name: get_str("model_name")?,
            version: json
                .get("version")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("{origin}: missing version"))?,
            input_dim,
            output_names,
            allowed_batch_sizes,
            artifact_pattern: get_str("artifact_pattern")?,
            ram_estimate_bytes: json
                .get("ram_estimate_bytes")
                .and_then(|v| v.as_u64())
                .unwrap_or(0),
            n_params: json.get("n_params").and_then(|v| v.as_u64()).unwrap_or(0),
            metrics: json.get("metrics").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn load(version_dir: &Path) -> Result<ModelSpec> {
        let path = version_dir.join("spec.json");
        let json = Json::parse_file(&path).context("loading spec")?;
        Self::parse(&json, &path.display().to_string())
    }

    /// HLO file for a given compiled batch size.
    pub fn artifact_path(&self, version_dir: &Path, batch: usize) -> PathBuf {
        version_dir.join(self.artifact_pattern.replace("{batch}", &batch.to_string()))
    }

    pub fn max_batch_size(&self) -> usize {
        *self.allowed_batch_sizes.last().unwrap()
    }
}

/// The artifacts root used by tests/examples: `$TS_ARTIFACTS` or
/// `<repo>/artifacts`.
pub fn default_artifacts_root() -> PathBuf {
    if let Ok(p) = std::env::var("TS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if `make artifacts` has produced the models examples need AND
/// this build can execute them. Without the `xla` feature the PJRT
/// backend is a stub, so artifact-driven tests/examples must skip even
/// when the files exist — loading would fail, not run.
pub fn artifacts_available() -> bool {
    cfg!(feature = "xla") && default_artifacts_root().join("mlp_classifier").is_dir()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"{
      "platform": "hlo", "signature": "classify",
      "model_name": "m", "version": 3,
      "input": {"name": "x", "shape": [-1, 32], "dtype": "f32"},
      "outputs": [{"name": "log_probs", "shape": [-1, 4], "dtype": "f32"},
                  {"name": "class", "shape": [-1], "dtype": "s32"}],
      "allowed_batch_sizes": [1, 4, 16],
      "artifact_pattern": "model_b{batch}.hlo.txt",
      "ram_estimate_bytes": 123456, "n_params": 999,
      "metrics": {"train_accuracy": 0.98}
    }"#;

    #[test]
    fn parse_full_spec() {
        let spec = ModelSpec::parse(&Json::parse(SPEC).unwrap(), "test").unwrap();
        assert_eq!(spec.model_name, "m");
        assert_eq!(spec.version, 3);
        assert_eq!(spec.input_dim, 32);
        assert_eq!(spec.output_names, vec!["log_probs", "class"]);
        assert_eq!(spec.allowed_batch_sizes, vec![1, 4, 16]);
        assert_eq!(spec.max_batch_size(), 16);
        assert_eq!(spec.ram_estimate_bytes, 123456);
        assert_eq!(
            spec.metrics.get("train_accuracy").unwrap().as_f64(),
            Some(0.98)
        );
    }

    #[test]
    fn artifact_path_substitution() {
        let spec = ModelSpec::parse(&Json::parse(SPEC).unwrap(), "test").unwrap();
        assert_eq!(
            spec.artifact_path(Path::new("/a/b/3"), 16),
            PathBuf::from("/a/b/3/model_b16.hlo.txt")
        );
    }

    #[test]
    fn parse_rejects_incomplete() {
        let bad = Json::parse(r#"{"platform": "hlo"}"#).unwrap();
        assert!(ModelSpec::parse(&bad, "t").is_err());
        let no_sizes = Json::parse(
            r#"{"platform":"hlo","signature":"s","model_name":"m","version":1,
                "input":{"shape":[-1,4]},"outputs":[],"allowed_batch_sizes":[],
                "artifact_pattern":"x"}"#,
        )
        .unwrap();
        assert!(ModelSpec::parse(&no_sizes, "t").is_err());
    }

    #[test]
    fn real_artifacts_parse_if_present() {
        let root = default_artifacts_root();
        if !artifacts_available() {
            return; // make artifacts not run yet
        }
        for model in ["mlp_classifier", "mlp_regressor"] {
            for v in [1u64, 2] {
                let dir = root.join(model).join(v.to_string());
                let spec = ModelSpec::load(&dir).unwrap();
                assert_eq!(spec.model_name, model);
                assert_eq!(spec.version, v);
                assert_eq!(spec.input_dim, 32);
                for &b in &spec.allowed_batch_sizes {
                    assert!(spec.artifact_path(&dir, b).exists());
                }
            }
        }
    }
}
