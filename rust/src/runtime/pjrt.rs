//! PJRT client wrapper: HLO text → compiled executable → typed run.
//!
//! Follows the pattern validated by /opt/xla-example/load_hlo: parse HLO
//! text into an `HloModuleProto` (the text parser reassigns instruction
//! ids, sidestepping the 64-bit-id proto incompatibility), wrap it in an
//! `XlaComputation`, compile on `PjRtClient::cpu()`, execute with
//! `Literal` inputs, and unpack the result tuple.
//!
//! The `xla` crate is only present in the full build environment, so
//! the real backend is gated behind the `xla` cargo feature (see
//! `rust/Cargo.toml`). Without it, [`XlaRuntime`] still constructs —
//! the rest of the stack (lifecycle, batching, RPC, TFS²) is fully
//! testable — but compiling/executing HLO returns a clear error.

use crate::base::tensor::{Tensor, TensorI32};
use anyhow::{bail, Result};

/// An output tensor from a model run.
///
/// Both variants are view types: batch-dimension trims and splits on an
/// `OutTensor` share the device buffer's storage instead of copying.
#[derive(Debug, Clone, PartialEq)]
pub enum OutTensor {
    F32(Tensor),
    I32(TensorI32),
}

impl OutTensor {
    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            OutTensor::F32(t) => Ok(t),
            OutTensor::I32(_) => bail!("output is i32, wanted f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI32> {
        match self {
            OutTensor::I32(t) => Ok(t),
            OutTensor::F32(_) => bail!("output is f32, wanted i32"),
        }
    }

    pub fn batch(&self) -> usize {
        match self {
            OutTensor::F32(t) => t.batch(),
            OutTensor::I32(t) => t.batch(),
        }
    }

    /// Zero-copy view of the first `n` batch rows (un-padding).
    pub fn truncate_batch(&self, n: usize) -> Result<OutTensor> {
        Ok(match self {
            OutTensor::F32(t) => OutTensor::F32(t.truncate_batch(n)?),
            OutTensor::I32(t) => OutTensor::I32(t.truncate_batch(n)?),
        })
    }

    /// Zero-copy split along the batch dimension — how the batching
    /// session scatters one merged device output back to its callers.
    pub fn split(&self, sizes: &[usize]) -> Result<Vec<OutTensor>> {
        Ok(match self {
            OutTensor::F32(t) => t.split(sizes)?.into_iter().map(OutTensor::F32).collect(),
            OutTensor::I32(t) => t.split(sizes)?.into_iter().map(OutTensor::I32).collect(),
        })
    }

    /// Concatenate along the batch dimension (the splitter's
    /// reassembly of an oversized request's chunk outputs). All parts
    /// must share one dtype.
    pub fn concat(parts: &[OutTensor]) -> Result<OutTensor> {
        match parts.first() {
            None => bail!("empty concat"),
            Some(OutTensor::F32(_)) => {
                let fs: Vec<Tensor> = parts
                    .iter()
                    .map(|p| p.as_f32().cloned())
                    .collect::<Result<_>>()?;
                Ok(OutTensor::F32(Tensor::concat(&fs)?))
            }
            Some(OutTensor::I32(_)) => {
                let is: Vec<TensorI32> = parts
                    .iter()
                    .map(|p| p.as_i32().cloned())
                    .collect::<Result<_>>()?;
                Ok(OutTensor::I32(TensorI32::concat(&is)?))
            }
        }
    }
}

pub use backend::{CompiledModel, XlaRuntime};

#[cfg(feature = "xla")]
mod backend {
    use super::{literal_to_tensor, OutTensor};
    use crate::base::tensor::Tensor;
    use anyhow::{anyhow, Context, Result};
    use std::path::Path;
    use std::sync::{Arc, Mutex};

    /// The process-wide PJRT client.
    ///
    /// Safety: XLA's PJRT CPU client is thread-safe (it is shared across
    /// server threads in TF-Serving itself); the `xla` crate just never
    /// asserted it. We wrap and assert. Compilation is serialized by a
    /// mutex out of caution; execution is concurrent.
    pub struct XlaRuntime {
        client: xla::PjRtClient,
        compile_lock: Mutex<()>,
    }

    unsafe impl Send for XlaRuntime {}
    unsafe impl Sync for XlaRuntime {}

    impl XlaRuntime {
        /// Create a CPU runtime.
        pub fn cpu() -> Result<Arc<Self>> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
            crate::log_info!(
                "PJRT client up: platform={} devices={}",
                client.platform_name(),
                client.device_count()
            );
            Ok(Arc::new(XlaRuntime { client, compile_lock: Mutex::new(()) }))
        }

        /// Shared singleton (compiling a client per test is expensive).
        pub fn shared() -> Result<Arc<Self>> {
            static SHARED: once_cell::sync::Lazy<Mutex<Option<Arc<XlaRuntime>>>> =
                once_cell::sync::Lazy::new(|| Mutex::new(None));
            let mut g = SHARED.lock().unwrap();
            if let Some(rt) = g.as_ref() {
                return Ok(Arc::clone(rt));
            }
            let rt = Self::cpu()?;
            *g = Some(Arc::clone(&rt));
            Ok(rt)
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Compile an HLO-text file into an executable.
        pub fn compile_hlo_file(self: &Arc<Self>, path: &Path) -> Result<CompiledModel> {
            let _g = self.compile_lock.lock().unwrap();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
            Ok(CompiledModel { exe, _runtime: Arc::clone(self) })
        }

        /// Compile HLO text from a string (tests).
        pub fn compile_hlo_text(self: &Arc<Self>, text: &str) -> Result<CompiledModel> {
            let tmp = std::env::temp_dir().join(format!(
                "tensorserve-hlo-{}-{:?}.txt",
                std::process::id(),
                std::thread::current().id()
            ));
            std::fs::write(&tmp, text).context("write temp hlo")?;
            let result = self.compile_hlo_file(&tmp);
            let _ = std::fs::remove_file(&tmp);
            result
        }
    }

    /// One compiled, loaded executable (fixed input shape).
    pub struct CompiledModel {
        exe: xla::PjRtLoadedExecutable,
        /// Keeps the client alive as long as its executables.
        _runtime: Arc<XlaRuntime>,
    }

    unsafe impl Send for CompiledModel {}
    unsafe impl Sync for CompiledModel {}

    impl CompiledModel {
        /// Execute with one f32 input tensor; returns the output tuple.
        pub fn run(&self, input: &Tensor) -> Result<Vec<OutTensor>> {
            let literal = xla::Literal::vec1(input.data())
                .reshape(&input.shape().iter().map(|&d| d as i64).collect::<Vec<_>>())
                .map_err(|e| anyhow!("reshape input: {e}"))?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[literal])
                .map_err(|e| anyhow!("execute: {e}"))?;
            let out = result
                .first()
                .and_then(|d| d.first())
                .ok_or_else(|| anyhow!("no output buffer"))?
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch output: {e}"))?;
            // aot.py lowers with return_tuple=True: unpack the tuple.
            let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
            parts.into_iter().map(literal_to_tensor).collect()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod backend {
    use super::OutTensor;
    use crate::base::tensor::Tensor;
    use anyhow::{bail, Result};
    use std::path::Path;
    use std::sync::Arc;

    const UNAVAILABLE: &str =
        "HLO execution requires the 'xla' feature (offline build has no PJRT backend)";

    /// Stub runtime: constructible (so servers and tests that never
    /// execute HLO keep working), but compilation reports the missing
    /// backend.
    pub struct XlaRuntime {
        _private: (),
    }

    impl XlaRuntime {
        pub fn cpu() -> Result<Arc<Self>> {
            Ok(Arc::new(XlaRuntime { _private: () }))
        }

        pub fn shared() -> Result<Arc<Self>> {
            static SHARED: once_cell::sync::Lazy<Arc<XlaRuntime>> =
                once_cell::sync::Lazy::new(|| Arc::new(XlaRuntime { _private: () }));
            Ok(Arc::clone(&SHARED))
        }

        pub fn platform_name(&self) -> String {
            "stub (no xla feature)".to_string()
        }

        pub fn compile_hlo_file(self: &Arc<Self>, path: &Path) -> Result<CompiledModel> {
            bail!("{UNAVAILABLE}: cannot compile {}", path.display())
        }

        pub fn compile_hlo_text(self: &Arc<Self>, _text: &str) -> Result<CompiledModel> {
            bail!("{UNAVAILABLE}")
        }
    }

    /// Never constructed in stub builds; `run` exists so callers
    /// type-check identically with and without the feature.
    pub struct CompiledModel {
        _private: std::convert::Infallible,
    }

    impl CompiledModel {
        pub fn run(&self, _input: &Tensor) -> Result<Vec<OutTensor>> {
            bail!("{UNAVAILABLE}")
        }
    }
}

#[cfg(feature = "xla")]
fn literal_to_tensor(lit: xla::Literal) -> Result<OutTensor> {
    use anyhow::anyhow;
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("output shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow!("f32 out: {e}"))?;
            Ok(OutTensor::F32(Tensor::new(dims, data)?))
        }
        xla::ElementType::S32 => {
            let data = lit.to_vec::<i32>().map_err(|e| anyhow!("i32 out: {e}"))?;
            Ok(OutTensor::I32(TensorI32::new(dims, data)?))
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_tensor_accessors() {
        let f = OutTensor::F32(Tensor::zeros(vec![2, 2]));
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
        assert_eq!(f.batch(), 2);
        let i = OutTensor::I32(TensorI32::new(vec![3], vec![1, 2, 3]).unwrap());
        assert!(i.as_i32().is_ok());
        assert_eq!(i.batch(), 3);
    }

    #[test]
    fn out_tensor_truncate_is_view() {
        let t = Tensor::zeros(vec![4, 2]);
        let o = OutTensor::F32(t.clone());
        let v = o.truncate_batch(2).unwrap();
        assert_eq!(v.batch(), 2);
        assert!(v.as_f32().unwrap().shares_storage(&t));
    }

    #[test]
    fn out_tensor_split_concat_roundtrip() {
        let f = OutTensor::F32(Tensor::matrix(vec![vec![1.0], vec![2.0], vec![3.0]]).unwrap());
        let parts = f.split(&[2, 1]).unwrap();
        assert_eq!(parts[0].batch(), 2);
        assert!(parts[1].as_f32().unwrap().shares_storage(f.as_f32().unwrap()));
        assert_eq!(OutTensor::concat(&parts).unwrap(), f);

        let i = OutTensor::I32(TensorI32::new(vec![3], vec![7, 8, 9]).unwrap());
        let parts = i.split(&[1, 2]).unwrap();
        assert_eq!(parts[1].as_i32().unwrap().data(), &[8, 9]);
        assert_eq!(OutTensor::concat(&parts).unwrap(), i);

        // Mixed dtypes never concat.
        assert!(OutTensor::concat(&[f, i]).is_err());
        assert!(OutTensor::concat(&[]).is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_constructs_but_cannot_compile() {
        let rt = XlaRuntime::shared().unwrap();
        assert!(rt.platform_name().contains("stub"));
        let err = rt.compile_hlo_text("HloModule x").unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
        assert!(rt
            .compile_hlo_file(std::path::Path::new("/nonexistent/x.hlo.txt"))
            .is_err());
    }

    #[cfg(feature = "xla")]
    mod with_backend {
        use super::*;
        use std::path::Path;
        use std::sync::Arc;

        /// Tiny hand-written HLO: f(x) = x + 1 over f32[2,2], as a 1-tuple.
        const ADD_ONE_HLO: &str = r#"
HloModule addone, entry_computation_layout={(f32[2,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main {
  x = f32[2,2]{1,0} parameter(0)
  one = f32[] constant(1)
  ones = f32[2,2]{1,0} broadcast(one), dimensions={}
  sum = f32[2,2]{1,0} add(x, ones)
  ROOT out = (f32[2,2]{1,0}) tuple(sum)
}
"#;

        #[test]
        fn compile_and_run_hlo_text() {
            let rt = XlaRuntime::shared().unwrap();
            let model = rt.compile_hlo_text(ADD_ONE_HLO).unwrap();
            let input = Tensor::matrix(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
            let out = model.run(&input).unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].as_f32().unwrap().data(), &[2.0, 3.0, 4.0, 5.0]);
        }

        #[test]
        fn run_is_reusable_and_thread_safe() {
            let rt = XlaRuntime::shared().unwrap();
            let model = Arc::new(rt.compile_hlo_text(ADD_ONE_HLO).unwrap());
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    let m = Arc::clone(&model);
                    std::thread::spawn(move || {
                        for i in 0..50 {
                            let v = (t * 50 + i) as f32;
                            let input = Tensor::new(vec![2, 2], vec![v; 4]).unwrap();
                            let out = m.run(&input).unwrap();
                            assert_eq!(out[0].as_f32().unwrap().data(), &[v + 1.0; 4]);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        }

        #[test]
        fn bad_hlo_fails_cleanly() {
            let rt = XlaRuntime::shared().unwrap();
            assert!(rt.compile_hlo_text("not hlo at all").is_err());
        }

        #[test]
        fn missing_file_fails_cleanly() {
            let rt = XlaRuntime::shared().unwrap();
            assert!(rt.compile_hlo_file(Path::new("/nonexistent/x.hlo.txt")).is_err());
        }
    }
}
