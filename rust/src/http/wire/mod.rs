//! Pluggable wire codecs for the REST data plane.
//!
//! One [`Codec`] seam, three implementations, negotiated per request:
//!
//! * [`json::ScalarJsonCodec`] — the original `util::json`-tree
//!   row/column codec (`application/json; codec=scalar`): the
//!   reference implementation every other codec must agree with.
//! * [`json::SimdJsonCodec`] — the default for `application/json`:
//!   identical semantics, but hot `{"instances": [[…]]}` bodies decode
//!   through the SWAR/SIMD engine in [`simd`] with zero intermediate
//!   `Json` tree; everything else transparently falls back to the
//!   scalar codec.
//! * [`binary::BinaryCodec`] — `application/x-tensorserve`: the RPC
//!   plane's tensor framing carried over REST, so latency-sensitive
//!   clients skip JSON entirely while keeping REST routing, limits and
//!   error semantics.
//!
//! Ingress is selected by `Content-Type` (unknown → 415), egress by
//! `Accept` (no match → 406, absent/`*/*` mirrors the ingress codec).
//! Error responses always use the uniform JSON `{"error": …}`
//! envelope regardless of the negotiated codecs — a client that can
//! speak any codec can always read a failure.

pub mod binary;
pub mod json;
pub mod simd;

use crate::http::codec::{ExamplesBody, PredictBody};
use crate::http::server::HttpResponse;
use crate::rpc::proto::Response;
use anyhow::Result;

/// The JSON media type (and the default when no `Content-Type` is
/// sent).
pub const CONTENT_TYPE_JSON: &str = "application/json";

/// The binary tensor-framing media type.
pub const CONTENT_TYPE_BINARY: &str = "application/x-tensorserve";

/// An encoded response payload: bytes plus the media type to answer
/// with.
pub struct Encoded {
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

/// One wire format: how data-plane request bodies decode and how
/// successful responses encode. Implementations are stateless — the
/// negotiated codec is shared per process and used concurrently.
pub trait Codec: Send + Sync {
    /// Short name for benches, logs and the `codec=` parameter.
    fn name(&self) -> &'static str;

    /// The media type this codec answers with.
    fn content_type(&self) -> &'static str;

    /// Decode a `:predict` body.
    fn decode_predict(&self, body: &[u8]) -> Result<PredictBody>;

    /// Decode a `:classify`/`:regress` body.
    fn decode_examples(&self, body: &[u8]) -> Result<ExamplesBody>;

    /// Encode a successful predict response. `row_format` mirrors the
    /// request format for JSON replies; binary ignores it.
    fn encode_predict(&self, resp: &Response, row_format: bool) -> Result<Encoded>;

    /// Encode a successful classify response.
    fn encode_classify(&self, model_version: u64, classes: &[i32], log_probs: &[Vec<f32>])
        -> Encoded;

    /// Encode a successful regress response.
    fn encode_regress(&self, model_version: u64, values: &[f32]) -> Encoded;
}

/// The process-wide codec instances.
pub fn scalar_json() -> &'static json::ScalarJsonCodec {
    static C: json::ScalarJsonCodec = json::ScalarJsonCodec;
    &C
}

pub fn simd_json() -> &'static json::SimdJsonCodec {
    static C: json::SimdJsonCodec = json::SimdJsonCodec;
    &C
}

pub fn binary() -> &'static binary::BinaryCodec {
    static C: binary::BinaryCodec = binary::BinaryCodec;
    &C
}

/// Strip parameters from a media type: `application/json; charset=…` →
/// `application/json`, lowercased and trimmed.
fn media_type(value: &str) -> String {
    value
        .split(';')
        .next()
        .unwrap_or("")
        .trim()
        .to_ascii_lowercase()
}

/// A `codec=` parameter on the media type, if present (`application/
/// json; codec=scalar` pins the reference implementation — used by the
/// differential harness and as an escape hatch).
fn codec_param(value: &str) -> Option<String> {
    for param in value.split(';').skip(1) {
        let mut kv = param.splitn(2, '=');
        let k = kv.next().unwrap_or("").trim().to_ascii_lowercase();
        if k == "codec" {
            return Some(kv.next().unwrap_or("").trim().to_ascii_lowercase());
        }
    }
    None
}

/// Select the ingress codec from a request `Content-Type`. `None`
/// (header absent) defaults to JSON. Unknown media types answer
/// `415 Unsupported Media Type` — in the uniform JSON error envelope —
/// instead of letting a JSON parse fail into a misleading 400.
pub fn ingress_codec(content_type: Option<&str>) -> Result<&'static dyn Codec, HttpResponse> {
    let value = match content_type {
        None => return Ok(simd_json()),
        Some(v) => v,
    };
    match media_type(value).as_str() {
        "" | "application/json" => match codec_param(value).as_deref() {
            None | Some("simd") => Ok(simd_json()),
            Some("scalar") => Ok(scalar_json()),
            Some(other) => Err(HttpResponse::error(
                415,
                &format!("unknown json codec parameter {other:?} (offered: simd, scalar)"),
            )),
        },
        "application/x-tensorserve" => Ok(binary()),
        other => Err(HttpResponse::error(
            415,
            &format!(
                "unsupported content-type {other:?} (offered: {CONTENT_TYPE_JSON}, \
                 {CONTENT_TYPE_BINARY})"
            ),
        )),
    }
}

/// Select the egress codec from a request `Accept` header. Absent,
/// `*/*` and `application/*` mirror the ingress codec's family; an
/// explicit media type must match an offered codec or the answer is
/// `406 Not Acceptable` (again in the JSON error envelope).
pub fn egress_codec(
    accept: Option<&str>,
    ingress: &'static dyn Codec,
) -> Result<&'static dyn Codec, HttpResponse> {
    let value = match accept {
        None => return Ok(ingress),
        Some(v) => v,
    };
    // An Accept list: any acceptable entry wins, most-specific match
    // first in the client's own order (no q-value weighting — the
    // gateway offers exactly two families).
    let mut saw_any = false;
    for entry in value.split(',') {
        match media_type(entry).as_str() {
            "" => continue,
            "*/*" | "application/*" => saw_any = true,
            "application/json" => {
                return Ok(match codec_param(entry).as_deref() {
                    Some("scalar") => scalar_json(),
                    _ => simd_json(),
                })
            }
            "application/x-tensorserve" => return Ok(binary()),
            _ => {}
        }
    }
    if saw_any {
        return Ok(ingress);
    }
    Err(HttpResponse::error(
        406,
        &format!(
            "no acceptable content-type in {value:?} (offered: {CONTENT_TYPE_JSON}, \
             {CONTENT_TYPE_BINARY})"
        ),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingress_negotiation() {
        assert_eq!(ingress_codec(None).unwrap().name(), "simd-json");
        assert_eq!(ingress_codec(Some("application/json")).unwrap().name(), "simd-json");
        assert_eq!(
            ingress_codec(Some("Application/JSON; charset=utf-8")).unwrap().name(),
            "simd-json"
        );
        assert_eq!(
            ingress_codec(Some("application/json; codec=scalar")).unwrap().name(),
            "json"
        );
        assert_eq!(
            ingress_codec(Some("application/x-tensorserve")).unwrap().name(),
            "binary"
        );
        for bad in ["text/csv", "application/xml", "multipart/form-data; boundary=x"] {
            let resp = ingress_codec(Some(bad)).unwrap_err();
            assert_eq!(resp.status, 415, "{bad}");
            assert!(String::from_utf8_lossy(&resp.body).contains("error"), "{bad}");
        }
    }

    #[test]
    fn egress_negotiation() {
        let json = simd_json() as &'static dyn Codec;
        let bin = binary() as &'static dyn Codec;
        assert_eq!(egress_codec(None, json).unwrap().name(), "simd-json");
        assert_eq!(egress_codec(None, bin).unwrap().name(), "binary");
        assert_eq!(egress_codec(Some("*/*"), bin).unwrap().name(), "binary");
        assert_eq!(egress_codec(Some("application/*"), json).unwrap().name(), "simd-json");
        assert_eq!(egress_codec(Some("application/json"), bin).unwrap().name(), "simd-json");
        assert_eq!(
            egress_codec(Some("application/x-tensorserve"), json).unwrap().name(),
            "binary"
        );
        assert_eq!(
            egress_codec(Some("text/html, application/json;q=0.9"), bin)
                .unwrap()
                .name(),
            "simd-json"
        );
        assert_eq!(
            egress_codec(Some("application/json; codec=scalar"), bin).unwrap().name(),
            "json"
        );
        let resp = egress_codec(Some("application/msgpack"), json).unwrap_err();
        assert_eq!(resp.status, 406);
    }
}
