//! The binary tensor codec: `application/x-tensorserve`.
//!
//! The RPC plane's tensor framing carried over REST. A request body is
//! exactly an `rpc::proto` payload — `signature` + named tensors for
//! `:predict`, `signature` + examples for `:classify`/`:regress` — with
//! no `ModelSpec` framed (the model comes from the URL path). Success
//! responses are [`Response::encode`] bytes; errors keep the uniform
//! JSON envelope so any client can read a failure.
//!
//! [`BinaryPredictStream`] is the incremental form used when a body
//! streams in (chunked transfer, or the reactor feeding bytes as they
//! land): framing headers are parsed as soon as enough bytes arrive
//! and tensor data is written f32-by-f32 straight into a pooled
//! buffer acquired up front — shape precedes data on the wire, so the
//! exact allocation is known before the first element. At most three
//! bytes of a split float are ever carried; nothing else is retained.

use super::{Codec, Encoded, CONTENT_TYPE_BINARY};
use crate::base::tensor::Tensor;
use crate::http::codec::{ExamplesBody, PredictBody};
use crate::rpc::proto::{self, Response};
use crate::util::pool::BufferPool;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

pub struct BinaryCodec;

impl Codec for BinaryCodec {
    fn name(&self) -> &'static str {
        "binary"
    }

    fn content_type(&self) -> &'static str {
        CONTENT_TYPE_BINARY
    }

    fn decode_predict(&self, body: &[u8]) -> Result<PredictBody> {
        let (signature, inputs) = proto::decode_predict_payload(body)?;
        // Named tensors are the column format's shape; a JSON reply to
        // a binary request therefore uses the "outputs" keying.
        Ok(PredictBody { signature, inputs, row_format: false })
    }

    fn decode_examples(&self, body: &[u8]) -> Result<ExamplesBody> {
        let (signature, examples) = proto::decode_examples_payload(body)?;
        Ok(ExamplesBody { signature, examples })
    }

    fn encode_predict(&self, resp: &Response, _row_format: bool) -> Result<Encoded> {
        match resp {
            Response::Predict { .. } => {
                Ok(Encoded { content_type: CONTENT_TYPE_BINARY, body: resp.encode() })
            }
            _ => bail!("predict produced an unexpected response variant"),
        }
    }

    fn encode_classify(
        &self,
        model_version: u64,
        classes: &[i32],
        log_probs: &[Vec<f32>],
    ) -> Encoded {
        let resp = Response::Classify {
            model_version,
            classes: classes.to_vec(),
            log_probs: log_probs.to_vec(),
        };
        Encoded { content_type: CONTENT_TYPE_BINARY, body: resp.encode() }
    }

    fn encode_regress(&self, model_version: u64, values: &[f32]) -> Encoded {
        let resp = Response::Regress { model_version, values: values.to_vec() };
        Encoded { content_type: CONTENT_TYPE_BINARY, body: resp.encode() }
    }
}

// ------------------------------------------------ incremental decode

/// Decode states, in wire order. Header fields accumulate in `hold`
/// until complete; tensor data bypasses `hold` entirely.
enum St {
    SigLen,
    Sig(usize),
    Count,
    NameLen,
    Name(usize),
    Rank,
    Dims(usize),
    DataLen,
    Data,
    Done,
}

/// Incremental decoder for a binary `:predict` body. Mirrors
/// [`proto::decode_predict_payload`]'s grammar and caps exactly;
/// [`finish`](Self::finish) yields the same tensors the whole-buffer
/// decode would.
pub struct BinaryPredictStream {
    st: St,
    hold: Vec<u8>,
    signature: String,
    remaining: usize,
    inputs: Vec<(String, Tensor)>,
    cur_name: String,
    cur_shape: Vec<usize>,
    cur_want: usize,
    buf: Option<Arc<[f32]>>,
    filled: usize,
    carry: [u8; 4],
    carry_len: usize,
    err: Option<anyhow::Error>,
}

impl Default for BinaryPredictStream {
    fn default() -> Self {
        Self::new()
    }
}

impl BinaryPredictStream {
    pub fn new() -> Self {
        BinaryPredictStream {
            st: St::SigLen,
            hold: Vec::new(),
            signature: String::new(),
            remaining: 0,
            inputs: Vec::new(),
            cur_name: String::new(),
            cur_shape: Vec::new(),
            cur_want: 0,
            buf: None,
            filled: 0,
            carry: [0; 4],
            carry_len: 0,
            err: None,
        }
    }

    /// Bytes a header state needs in `hold` before it can step.
    fn need(&self) -> usize {
        match self.st {
            St::SigLen | St::Count | St::NameLen | St::Rank | St::DataLen => 4,
            St::Sig(n) | St::Name(n) => n,
            St::Dims(rank) => rank * 4,
            St::Data | St::Done => 0,
        }
    }

    fn fail(&mut self, e: anyhow::Error) {
        self.err = Some(e);
        self.buf = None;
        self.hold.clear();
    }

    /// Feed the next slice of body bytes. Errors are latched and
    /// reported by [`finish`](Self::finish).
    pub fn feed(&mut self, mut chunk: &[u8]) {
        while self.err.is_none() {
            match self.st {
                St::Data => {
                    if self.filled == self.cur_want && self.carry_len == 0 {
                        if let Err(e) = self.finish_tensor() {
                            self.fail(e);
                        }
                        continue;
                    }
                    if chunk.is_empty() {
                        return;
                    }
                    if self.carry_len > 0 || chunk.len() < 4 {
                        // Complete (or start) a split float.
                        let take = (4 - self.carry_len).min(chunk.len());
                        self.carry[self.carry_len..self.carry_len + take]
                            .copy_from_slice(&chunk[..take]);
                        self.carry_len += take;
                        chunk = &chunk[take..];
                        if self.carry_len == 4 {
                            let v = f32::from_le_bytes(self.carry);
                            self.carry_len = 0;
                            self.write_f32(v);
                        }
                        continue;
                    }
                    let whole = (chunk.len() / 4).min(self.cur_want - self.filled);
                    if whole > 0 {
                        let buf = Arc::get_mut(self.buf.as_mut().expect("staging buffer"))
                            .expect("staging buffer uniquely owned");
                        for (dst, src) in buf[self.filled..self.filled + whole]
                            .iter_mut()
                            .zip(chunk.chunks_exact(4))
                        {
                            *dst = f32::from_le_bytes(src.try_into().unwrap());
                        }
                        self.filled += whole;
                        chunk = &chunk[whole * 4..];
                    }
                }
                St::Done => {
                    if chunk.is_empty() {
                        return;
                    }
                    self.fail(anyhow!("trailing bytes in message"));
                }
                _ => {
                    let need = self.need();
                    if self.hold.len() < need {
                        let take = (need - self.hold.len()).min(chunk.len());
                        if take == 0 {
                            return; // starved: wait for the next chunk
                        }
                        self.hold.extend_from_slice(&chunk[..take]);
                        chunk = &chunk[take..];
                    }
                    if self.hold.len() == need {
                        let hold = std::mem::take(&mut self.hold);
                        if let Err(e) = self.step(&hold) {
                            self.fail(e);
                        }
                    }
                }
            }
        }
    }

    /// A header field is complete: validate it (same caps as the
    /// whole-buffer `Reader`) and advance.
    fn step(&mut self, hold: &[u8]) -> Result<()> {
        let u32_at = |i: usize| u32::from_le_bytes(hold[i * 4..i * 4 + 4].try_into().unwrap());
        match self.st {
            St::SigLen => {
                let n = u32_at(0) as usize;
                if n > 1 << 20 {
                    bail!("implausible string length {n}");
                }
                self.st = St::Sig(n);
            }
            St::Sig(_) => {
                self.signature = std::str::from_utf8(hold)?.to_string();
                self.st = St::Count;
            }
            St::Count => {
                let n = u32_at(0) as usize;
                if n > 1 << 16 {
                    bail!("implausible input count {n}");
                }
                self.remaining = n;
                self.st = if n == 0 { St::Done } else { St::NameLen };
            }
            St::NameLen => {
                let n = u32_at(0) as usize;
                if n > 1 << 20 {
                    bail!("implausible string length {n}");
                }
                self.st = St::Name(n);
            }
            St::Name(_) => {
                self.cur_name = std::str::from_utf8(hold)?.to_string();
                self.st = St::Rank;
            }
            St::Rank => {
                let rank = u32_at(0) as usize;
                if rank > 8 {
                    bail!("implausible rank {rank}");
                }
                self.st = St::Dims(rank);
            }
            St::Dims(rank) => {
                self.cur_shape = (0..rank).map(|i| u32_at(i) as usize).collect();
                self.cur_want = self
                    .cur_shape
                    .iter()
                    .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                    .ok_or_else(|| anyhow!("tensor shape {:?} overflows", self.cur_shape))?;
                self.st = St::DataLen;
            }
            St::DataLen => {
                let n = u32_at(0) as usize;
                if n != self.cur_want {
                    bail!(
                        "tensor data length {n} != shape {:?} product {}",
                        self.cur_shape,
                        self.cur_want
                    );
                }
                self.buf = Some(BufferPool::global().acquire(self.cur_want));
                self.filled = 0;
                self.st = St::Data;
            }
            St::Data | St::Done => unreachable!("data states never hold"),
        }
        Ok(())
    }

    fn write_f32(&mut self, v: f32) {
        let buf = Arc::get_mut(self.buf.as_mut().expect("staging buffer"))
            .expect("staging buffer uniquely owned");
        buf[self.filled] = v;
        self.filled += 1;
    }

    fn finish_tensor(&mut self) -> Result<()> {
        let storage = self.buf.take().expect("staging buffer");
        let shape = std::mem::take(&mut self.cur_shape);
        let tensor = Tensor::from_shared(shape, storage, 0)?;
        self.inputs.push((std::mem::take(&mut self.cur_name), tensor));
        self.remaining -= 1;
        self.st = if self.remaining == 0 { St::Done } else { St::NameLen };
        Ok(())
    }

    /// Complete the decode. Errors if any fed byte violated the
    /// grammar or the body stopped mid-field.
    pub fn finish(mut self) -> Result<PredictBody> {
        // A zero-element tensor completes without needing data bytes.
        self.feed(&[]);
        if let Some(e) = self.err.take() {
            return Err(e);
        }
        match self.st {
            St::Done => Ok(PredictBody {
                signature: self.signature,
                inputs: self.inputs,
                row_format: false,
            }),
            _ => {
                if let Some(storage) = self.buf.take() {
                    BufferPool::global().release(storage);
                }
                bail!("truncated binary predict payload")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::size_class;

    fn payload(signature: &str, inputs: &[(String, Tensor)]) -> Vec<u8> {
        let mut out = Vec::new();
        proto::encode_predict_payload(&mut out, signature, inputs);
        out
    }

    fn tensor(shape: Vec<usize>, data: &[f32]) -> Tensor {
        Tensor::build_with(shape, &BufferPool::global(), |buf| {
            buf.copy_from_slice(data);
        })
    }

    fn assert_same(a: &PredictBody, b: &PredictBody) {
        assert_eq!(a.signature, b.signature);
        assert_eq!(a.inputs.len(), b.inputs.len());
        for ((an, at), (bn, bt)) in a.inputs.iter().zip(b.inputs.iter()) {
            assert_eq!(an, bn);
            assert_eq!(at.shape(), bt.shape());
            let ab: Vec<u32> = at.data().iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = bt.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb);
        }
    }

    #[test]
    fn whole_and_streamed_decode_agree() {
        let inputs = vec![
            ("x".to_string(), tensor(vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0])),
            ("y".to_string(), tensor(vec![1], &[-0.5])),
        ];
        let body = payload("serving_default", &inputs);

        let whole = BinaryCodec.decode_predict(&body).unwrap();
        assert_same(
            &whole,
            &PredictBody { signature: "serving_default".into(), inputs, row_format: false },
        );

        // Byte-at-a-time streaming must land on identical tensors.
        let mut stream = BinaryPredictStream::new();
        for b in &body {
            stream.feed(std::slice::from_ref(b));
        }
        let streamed = stream.finish().unwrap();
        assert_same(&whole, &streamed);
        // Streamed tensors live in pooled class-sized storage.
        let (_, t) = &streamed.inputs[0];
        assert_eq!(t.storage().len(), size_class(6));
    }

    #[test]
    fn streamed_decode_rejects_what_whole_decode_rejects() {
        let good = payload("s", &[("x".to_string(), tensor(vec![2], &[1.0, 2.0]))]);
        let cases: Vec<Vec<u8>> = vec![
            good[..good.len() - 1].to_vec(),                  // truncated data
            good[..5].to_vec(),                               // truncated header
            { let mut b = good.clone(); b.push(0); b },       // trailing byte
            { let mut b = good.clone(); b[0] = 0xff; b[1] = 0xff; b[2] = 0xff; b }, // huge sig len
            Vec::new(),                                       // empty body
        ];
        for body in cases {
            let whole = BinaryCodec.decode_predict(&body);
            let mut stream = BinaryPredictStream::new();
            stream.feed(&body);
            let streamed = stream.finish();
            assert_eq!(whole.is_err(), streamed.is_err(), "{body:?}");
            assert!(whole.is_err(), "all cases here are invalid");
        }
    }

    #[test]
    fn zero_tensors_and_zero_elements() {
        let empty = payload("sig", &[]);
        let mut stream = BinaryPredictStream::new();
        stream.feed(&empty);
        let parsed = stream.finish().unwrap();
        assert_eq!(parsed.signature, "sig");
        assert!(parsed.inputs.is_empty());

        let zero_elem = payload("s", &[("x".to_string(), tensor(vec![0], &[]))]);
        let mut stream = BinaryPredictStream::new();
        stream.feed(&zero_elem);
        let parsed = stream.finish().unwrap();
        assert_eq!(parsed.inputs.len(), 1);
        assert_eq!(parsed.inputs[0].1.shape(), &[0]);
    }

    #[test]
    fn response_roundtrip_through_binary_encoding() {
        let enc = BinaryCodec.encode_regress(7, &[0.25, 0.75]);
        assert_eq!(enc.content_type, CONTENT_TYPE_BINARY);
        match Response::decode(&enc.body).unwrap() {
            Response::Regress { model_version, values } => {
                assert_eq!(model_version, 7);
                assert_eq!(values, vec![0.25, 0.75]);
            }
            other => panic!("unexpected variant {other:?}"),
        }
    }
}
