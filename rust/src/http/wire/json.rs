//! The JSON wire codecs.
//!
//! [`ScalarJsonCodec`] is the original implementation: parse the body
//! into a `util::json` tree, then walk it into pooled tensors
//! (`http::codec`). It is the reference semantics — every other codec
//! is defined as "agrees with scalar".
//!
//! [`SimdJsonCodec`] is the default for `application/json`. Predict
//! bodies first run through the complete-or-bail SWAR/SIMD engine
//! ([`super::simd`]): hot `{"instances": [[…]]}` shapes decode with no
//! intermediate `Json` tree, digits scanned a block at a time, floats
//! written straight into pooled `BufferPool` storage. Anything the
//! engine cannot prove it parses identically — column format, nested
//! envelopes, string escapes, exotic numbers — bails and the retained
//! raw bytes re-parse through the scalar codec, so the observable
//! result (success or exact error) never depends on which path ran.

use super::{Codec, Encoded, CONTENT_TYPE_JSON};
use crate::http::codec::{self, ExamplesBody, PredictBody};
use crate::rpc::proto::Response;
use anyhow::Result;

fn encode_predict_json(resp: &Response, row_format: bool) -> Result<Encoded> {
    let json = codec::predict_response_json(resp, row_format)?;
    Ok(Encoded { content_type: CONTENT_TYPE_JSON, body: json.to_string().into_bytes() })
}

fn encode_classify_json(model_version: u64, classes: &[i32], log_probs: &[Vec<f32>]) -> Encoded {
    let json = codec::classify_response_json(model_version, classes, log_probs);
    Encoded { content_type: CONTENT_TYPE_JSON, body: json.to_string().into_bytes() }
}

fn encode_regress_json(model_version: u64, values: &[f32]) -> Encoded {
    let json = codec::regress_response_json(model_version, values);
    Encoded { content_type: CONTENT_TYPE_JSON, body: json.to_string().into_bytes() }
}

/// The reference JSON codec: full `util::json` tree walk.
pub struct ScalarJsonCodec;

impl Codec for ScalarJsonCodec {
    fn name(&self) -> &'static str {
        "json"
    }

    fn content_type(&self) -> &'static str {
        CONTENT_TYPE_JSON
    }

    fn decode_predict(&self, body: &[u8]) -> Result<PredictBody> {
        codec::parse_predict_body(body)
    }

    fn decode_examples(&self, body: &[u8]) -> Result<ExamplesBody> {
        codec::parse_examples_body(body)
    }

    fn encode_predict(&self, resp: &Response, row_format: bool) -> Result<Encoded> {
        encode_predict_json(resp, row_format)
    }

    fn encode_classify(
        &self,
        model_version: u64,
        classes: &[i32],
        log_probs: &[Vec<f32>],
    ) -> Encoded {
        encode_classify_json(model_version, classes, log_probs)
    }

    fn encode_regress(&self, model_version: u64, values: &[f32]) -> Encoded {
        encode_regress_json(model_version, values)
    }
}

/// The SWAR/SIMD-accelerated JSON codec. Same observable semantics as
/// [`ScalarJsonCodec`]; hot predict bodies skip the `Json` tree.
pub struct SimdJsonCodec;

impl Codec for SimdJsonCodec {
    fn name(&self) -> &'static str {
        "simd-json"
    }

    fn content_type(&self) -> &'static str {
        CONTENT_TYPE_JSON
    }

    fn decode_predict(&self, body: &[u8]) -> Result<PredictBody> {
        match super::simd::parse_predict_fast(body) {
            super::simd::FastResult::Parsed(parsed) => Ok(parsed),
            super::simd::FastResult::Fallback(raw) => codec::parse_predict_body(&raw),
        }
    }

    fn decode_examples(&self, body: &[u8]) -> Result<ExamplesBody> {
        // Examples are nested feature maps — tree parse is the honest
        // path; the SIMD engine only targets numeric tensor bodies.
        codec::parse_examples_body(body)
    }

    fn encode_predict(&self, resp: &Response, row_format: bool) -> Result<Encoded> {
        encode_predict_json(resp, row_format)
    }

    fn encode_classify(
        &self,
        model_version: u64,
        classes: &[i32],
        log_probs: &[Vec<f32>],
    ) -> Encoded {
        encode_classify_json(model_version, classes, log_probs)
    }

    fn encode_regress(&self, model_version: u64, values: &[f32]) -> Encoded {
        encode_regress_json(model_version, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decode_both(body: &[u8]) -> (Result<PredictBody>, Result<PredictBody>) {
        (ScalarJsonCodec.decode_predict(body), SimdJsonCodec.decode_predict(body))
    }

    #[test]
    fn simd_codec_matches_scalar_on_hot_and_cold_bodies() {
        let bodies: [&[u8]; 6] = [
            br#"{"instances": [[1.5, 2.5], [3.0, 4.0]]}"#,
            br#"{"signature_name": "sig", "instances": [1, 2, 3]}"#,
            // Cold shapes: column format, envelope rows, escapes.
            br#"{"inputs": {"x": [[1, 2]]}}"#,
            br#"{"instances": [{"x": [1.0]}, {"x": [2.0]}]}"#,
            br#"{"signature_name": "a\nb", "instances": [[1]]}"#,
            br#"not json at all"#,
        ];
        for body in bodies {
            let (scalar, simd) = decode_both(body);
            match (scalar, simd) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.signature, b.signature);
                    assert_eq!(a.row_format, b.row_format);
                    assert_eq!(a.inputs.len(), b.inputs.len());
                    for ((an, at), (bn, bt)) in a.inputs.iter().zip(b.inputs.iter()) {
                        assert_eq!(an, bn);
                        assert_eq!(at.shape(), bt.shape());
                        let ab: Vec<u32> = at.data().iter().map(|v| v.to_bits()).collect();
                        let bb: Vec<u32> = bt.data().iter().map(|v| v.to_bits()).collect();
                        assert_eq!(ab, bb);
                    }
                }
                (Err(a), Err(b)) => assert_eq!(a.to_string(), b.to_string()),
                (a, b) => panic!(
                    "paths disagree on {:?}: scalar={:?} simd={:?}",
                    String::from_utf8_lossy(body),
                    a.map(|p| p.inputs.len()),
                    b.map(|p| p.inputs.len()),
                ),
            }
        }
    }
}
