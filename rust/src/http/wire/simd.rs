//! SWAR/SIMD fast path for the hot `{"instances": [[...]]}` parse.
//!
//! The scalar codec walks every request body through a generic
//! [`crate::util::json::Json`] tree — one heap node per number — before
//! tensor data reaches pooled storage. For the dominant REST payload
//! (a row-format predict body whose rows are bare numbers or flat
//! number arrays) this module decodes the body in a single pass with
//! **zero intermediate tree allocations**: digit runs are located with
//! SSE2/AVX2 (runtime-detected, portable SWAR fallback), eight digits
//! are folded to an integer per multiply chain, floats compose via the
//! shared Clinger window in [`crate::util::json`], and every element is
//! written straight into a pooled [`BufferPool`] buffer that becomes
//! the request [`Tensor`]'s storage without a copy.
//!
//! ## Complete-or-bail
//!
//! The engine never produces its own errors. Either it **completes**
//! — and the result is bit-identical to what
//! [`crate::http::codec::parse_predict_body`] would build, because both
//! paths share one number parser and one pool discipline — or it
//! **bails** and the caller re-parses the retained bytes through the
//! scalar codec, which then produces the canonical result or error.
//! Anything outside the strict hot grammar bails: column format,
//! `{name: row}` envelopes, string escapes, non-ASCII bytes, unknown
//! keys, ragged rows, element counts past
//! [`crate::http::codec::MAX_TENSOR_ELEMS`]. This is what makes the
//! differential fuzz guarantee (`rust/tests/codec_fuzz.rs`) structural
//! rather than statistical.
//!
//! ## Incremental feeding
//!
//! [`FastPredictParser`] accepts the body in arbitrary chunks (the
//! chunked-transfer path feeds it straight from the socket). The
//! cursor only advances past complete tokens, so a chunk boundary in
//! the middle of a number or string simply parks the parse until more
//! bytes arrive; staged floats live in pool-class buffers that grow by
//! class doubling, so `finish()` hands the final buffer to the tensor
//! zero-copy (the last class always equals `size_class(n)` — exactly
//! what the scalar path's `try_build_with` produces).

use crate::base::tensor::Tensor;
use crate::http::codec::{PredictBody, MAX_TENSOR_ELEMS};
use crate::util::json;
use crate::util::pool::BufferPool;
use std::sync::Arc;

// ------------------------------------------------------ CPU dispatch

/// Vector tier the digit scanner runs at, resolved once per process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable 8-bytes-per-word bit tricks (non-x86 fallback).
    Swar,
    /// 16-byte vectors — baseline on every x86_64 target.
    Sse2,
    /// 32-byte vectors, runtime-detected via CPUID.
    Avx2,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Swar => "swar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

/// The tier this CPU supports (cached after the first probe).
pub fn simd_level() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        use std::sync::atomic::{AtomicU8, Ordering};
        static LEVEL: AtomicU8 = AtomicU8::new(0);
        match LEVEL.load(Ordering::Relaxed) {
            1 => SimdLevel::Sse2,
            2 => SimdLevel::Avx2,
            _ => {
                let level = if std::is_x86_feature_detected!("avx2") {
                    SimdLevel::Avx2
                } else {
                    SimdLevel::Sse2
                };
                LEVEL.store(
                    if level == SimdLevel::Avx2 { 2 } else { 1 },
                    Ordering::Relaxed,
                );
                level
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    SimdLevel::Swar
}

// --------------------------------------------------- digit-run scans

/// True when all eight bytes of `v` are ASCII digits (Lemire's SWAR
/// range check: high nibbles must be 0x3 and adding 6 to each byte
/// must not carry into the high nibble).
#[inline]
fn is_eight_digits(v: u64) -> bool {
    ((v & 0xF0F0_F0F0_F0F0_F0F0)
        | ((v.wrapping_add(0x0606_0606_0606_0606) & 0xF0F0_F0F0_F0F0_F0F0) >> 4))
        == 0x3333_3333_3333_3333
}

/// Fold eight ASCII digit bytes (little-endian load, most significant
/// digit in the low byte) into their decimal value: three multiply
/// steps pair up adjacent lanes instead of eight sequential
/// `*10 + d` dependencies.
#[inline]
fn parse_eight_digits(v: u64) -> u32 {
    let v = v & 0x0F0F_0F0F_0F0F_0F0F;
    let v = v.wrapping_mul(2561) >> 8;
    let v = (v & 0x00FF_00FF_00FF_00FF).wrapping_mul(6_553_601) >> 16;
    ((v & 0x0000_FFFF_0000_FFFF).wrapping_mul(42_949_672_960_001) >> 32) as u32
}

#[inline]
fn swar_skip_digits(bytes: &[u8]) -> usize {
    let mut i = 0;
    while i + 8 <= bytes.len() {
        let v = u64::from_le_bytes(bytes[i..i + 8].try_into().unwrap());
        if !is_eight_digits(v) {
            break;
        }
        i += 8;
    }
    while i < bytes.len() && bytes[i].is_ascii_digit() {
        i += 1;
    }
    i
}

#[cfg(target_arch = "x86_64")]
fn sse2_skip_digits(bytes: &[u8]) -> usize {
    // SSE2 is part of the x86_64 baseline, so no runtime gate is
    // needed; the intrinsics are `unsafe fn` purely as an API matter.
    use std::arch::x86_64::*;
    let mut i = 0;
    unsafe {
        while i + 16 <= bytes.len() {
            let v = _mm_loadu_si128(bytes.as_ptr().add(i) as *const __m128i);
            // Signed compares: bytes ≥ 0x80 read as negative, which the
            // `< '0'` arm flags as non-digit — exactly right.
            let below = _mm_cmplt_epi8(v, _mm_set1_epi8(b'0' as i8));
            let above = _mm_cmpgt_epi8(v, _mm_set1_epi8(b'9' as i8));
            let non_digit = _mm_movemask_epi8(_mm_or_si128(below, above)) as u32;
            if non_digit != 0 {
                return i + non_digit.trailing_zeros() as usize;
            }
            i += 16;
        }
    }
    i + swar_skip_digits(&bytes[i..])
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_skip_digits(bytes: &[u8]) -> usize {
    use std::arch::x86_64::*;
    let mut i = 0;
    while i + 32 <= bytes.len() {
        let v = _mm256_loadu_si256(bytes.as_ptr().add(i) as *const __m256i);
        let below = _mm256_cmpgt_epi8(_mm256_set1_epi8(b'0' as i8), v);
        let above = _mm256_cmpgt_epi8(v, _mm256_set1_epi8(b'9' as i8));
        let non_digit = _mm256_movemask_epi8(_mm256_or_si256(below, above)) as u32;
        if non_digit != 0 {
            return i + non_digit.trailing_zeros() as usize;
        }
        i += 32;
    }
    i + swar_skip_digits(&bytes[i..])
}

/// Length of the ASCII-digit run at the head of `bytes`, scanned at
/// the best vector width this CPU offers.
#[inline]
pub fn skip_digits(bytes: &[u8]) -> usize {
    #[cfg(target_arch = "x86_64")]
    {
        if bytes.len() >= 32 && simd_level() == SimdLevel::Avx2 {
            // Safety: dispatch is gated on the CPUID probe above.
            return unsafe { avx2_skip_digits(bytes) };
        }
        sse2_skip_digits(bytes)
    }
    #[cfg(not(target_arch = "x86_64"))]
    swar_skip_digits(bytes)
}

/// Accumulate a digit run into `mantissa`, eight digits per multiply
/// chain. Callers guarantee ≤ 19 total digits, so nothing wraps.
#[inline]
fn accumulate_digits(mantissa: &mut u64, digits: &[u8]) {
    let mut i = 0;
    while i + 8 <= digits.len() {
        let v = u64::from_le_bytes(digits[i..i + 8].try_into().unwrap());
        *mantissa = mantissa.wrapping_mul(100_000_000) + parse_eight_digits(v) as u64;
        i += 8;
    }
    for &b in &digits[i..] {
        *mantissa = *mantissa * 10 + (b - b'0') as u64;
    }
}

// ------------------------------------------------------ token scans

#[inline]
fn skip_ws(bytes: &[u8]) -> usize {
    let mut i = 0;
    while i < bytes.len() && matches!(bytes[i], b' ' | b'\t' | b'\n' | b'\r') {
        i += 1;
    }
    i
}

enum NumScan {
    /// The token runs to the end of the available bytes and the body
    /// is not complete yet — retry once more arrive.
    NeedMore,
    /// Not a token the fast grammar owns; the scalar path decides.
    Bail,
    /// Value plus token length. Bit-identical to what the scalar
    /// parser produces for the same bytes (shared compose + fallback).
    Ok(f64, usize),
}

/// Parse one number token at the head of `bytes`. `eof` means no more
/// bytes will ever arrive, so a token touching the end is complete.
fn parse_number_at(bytes: &[u8], eof: bool) -> NumScan {
    let mut i = 0;
    let neg = bytes[0] == b'-';
    if neg {
        i += 1;
    }
    let int_start = i;
    let int_run = skip_digits(&bytes[i..]);
    i += int_run;
    let mut digits = int_run;
    let mut frac_run = 0usize;
    let mut frac_start = 0usize;
    if bytes.get(i) == Some(&b'.') {
        i += 1;
        frac_start = i;
        frac_run = skip_digits(&bytes[i..]);
        i += frac_run;
        digits += frac_run;
    }
    let mut has_exp = false;
    let mut exp_neg = false;
    let mut exp_run = 0usize;
    let mut exp_start = 0usize;
    if matches!(bytes.get(i), Some(b'e' | b'E')) {
        has_exp = true;
        i += 1;
        if matches!(bytes.get(i), Some(b'+' | b'-')) {
            exp_neg = bytes[i] == b'-';
            i += 1;
        }
        exp_start = i;
        exp_run = skip_digits(&bytes[i..]);
        i += exp_run;
    }
    if i == bytes.len() && !eof {
        // More digits / '.' / exponent may still arrive.
        return NumScan::NeedMore;
    }
    if (1..=19).contains(&digits) && (!has_exp || (1..=18).contains(&exp_run)) {
        let mut mantissa = 0u64;
        accumulate_digits(&mut mantissa, &bytes[int_start..int_start + int_run]);
        accumulate_digits(&mut mantissa, &bytes[frac_start..frac_start + frac_run]);
        let mut exp: i64 = 0;
        for &b in &bytes[exp_start..exp_start + exp_run] {
            exp = exp.saturating_mul(10).saturating_add((b - b'0') as i64);
        }
        let e10 = (if exp_neg { -exp } else { exp }).saturating_sub(frac_run as i64);
        if let Some(v) = json::compose_f64_exact(mantissa, e10) {
            return NumScan::Ok(if neg { -v } else { v }, i);
        }
    }
    // Odd-but-possibly-valid spelling ("1.", 20+ digits, huge
    // exponent): defer to the shared scalar scanner so the value — or
    // the rejection — is exactly what the tree parser would produce.
    match json::scan_number(&bytes[..i]) {
        (Some(v), consumed) if consumed == i => NumScan::Ok(v, i),
        _ => NumScan::Bail,
    }
}

enum StrScan {
    NeedMore,
    Bail,
    /// Byte length of the content between the quotes; the full token
    /// is `content + 2`.
    Ok(usize),
}

/// Scan a string token starting at the opening quote. Only plain
/// printable ASCII is in the fast grammar — any escape or non-ASCII
/// byte bails to the scalar path (which handles full JSON strings).
fn scan_simple_string(bytes: &[u8]) -> StrScan {
    debug_assert_eq!(bytes[0], b'"');
    for (i, &b) in bytes.iter().enumerate().skip(1) {
        match b {
            b'"' => return StrScan::Ok(i - 1),
            b'\\' => return StrScan::Bail,
            0x20..=0x7e => {}
            _ => return StrScan::Bail,
        }
    }
    StrScan::NeedMore
}

// --------------------------------------------------- pooled staging

/// Append-only f32 staging in pool-class buffers. Growth re-acquires
/// the next class and copies (amortized O(n)); because growth only
/// happens when the current class is full, the final buffer's class is
/// always `size_class(len)` — the same buffer shape
/// `Tensor::try_build_with` would have acquired, so `finish()` turns
/// it into tensor storage without a copy.
struct Staging {
    pool: Arc<BufferPool>,
    buf: Option<Arc<[f32]>>,
    len: usize,
}

impl Staging {
    fn new() -> Self {
        Staging { pool: BufferPool::global(), buf: None, len: 0 }
    }

    #[inline]
    fn push(&mut self, v: f32) {
        let cap = self.buf.as_ref().map_or(0, |b| b.len());
        if self.len == cap {
            let mut grown = self.pool.acquire(cap + 1);
            if let Some(old) = self.buf.take() {
                let dst = Arc::get_mut(&mut grown).expect("pool buffer uniquely owned");
                dst[..self.len].copy_from_slice(&old[..self.len]);
                self.pool.release(old);
            }
            self.buf = Some(grown);
        }
        let buf = self.buf.as_mut().unwrap();
        Arc::get_mut(buf).expect("pool buffer uniquely owned")[self.len] = v;
        self.len += 1;
    }

    /// Return the staged buffer to the pool (bail path).
    fn discard(&mut self) {
        if let Some(buf) = self.buf.take() {
            self.pool.release(buf);
        }
        self.len = 0;
    }
}

// ------------------------------------------------------- the engine

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Key {
    Signature,
    Instances,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RowKind {
    /// Bare-number rows → shape `[n, 1]`.
    Scalar,
    /// Flat-array rows of this width → shape `[n, width]`.
    Array(usize),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum St {
    /// Expect the root `{`.
    Start,
    /// Inside the root object: expect a key string.
    RootKey,
    /// Expect `:` after a key.
    RootColon,
    /// Expect the value for `pending_key`.
    RootValue,
    /// Expect a row value, or `]` when the array is still empty.
    Row,
    /// After a row: expect `,` or `]`.
    RowSep,
    /// Inside a row array, first element: expect a number or `]`.
    ArrFirst,
    /// Inside a row array: expect a number.
    ArrVal,
    /// Inside a row array, after a number: expect `,` or `]`.
    ArrSep,
    /// After a root value: expect `,` or `}`.
    RootSep,
    /// Root object closed: only whitespace may follow.
    End,
}

/// Outcome of a finished fast parse.
pub enum FastResult {
    /// The body matched the hot grammar; the result is bit-identical
    /// to the scalar codec's, built without a `Json` tree.
    Parsed(PredictBody),
    /// The body (returned whole) needs the scalar codec.
    Fallback(Vec<u8>),
}

/// Incremental fast parser for row-format predict bodies. Feed the
/// body in any chunking; `finish()` yields either the decoded
/// [`PredictBody`] or the retained bytes for the scalar fallback.
pub struct FastPredictParser {
    /// The full body so far. Retained so a bail at any point can hand
    /// the scalar codec exactly what it would have seen — the fallback
    /// costs what the old buffered path always cost, no more.
    raw: Vec<u8>,
    cursor: usize,
    st: St,
    bailed: bool,
    pending_key: Key,
    signature: Option<String>,
    saw_instances: bool,
    row_kind: Option<RowKind>,
    rows: usize,
    row_pos: usize,
    staging: Staging,
}

impl Default for FastPredictParser {
    fn default() -> Self {
        Self::new()
    }
}

impl FastPredictParser {
    pub fn new() -> Self {
        FastPredictParser {
            raw: Vec::new(),
            cursor: 0,
            st: St::Start,
            bailed: false,
            pending_key: Key::Instances,
            signature: None,
            saw_instances: false,
            row_kind: None,
            rows: 0,
            row_pos: 0,
            staging: Staging::new(),
        }
    }

    /// Append body bytes and advance the parse as far as they allow.
    pub fn feed(&mut self, chunk: &[u8]) {
        self.raw.extend_from_slice(chunk);
        if !self.bailed {
            self.advance(false);
        }
    }

    /// Total body bytes received so far.
    pub fn body_len(&self) -> usize {
        self.raw.len()
    }

    /// Complete the parse. `Parsed` only when the whole body matched
    /// the hot grammar; otherwise the raw bytes come back for the
    /// scalar codec.
    pub fn finish(mut self) -> FastResult {
        if !self.bailed {
            self.advance(true);
        }
        let done = !self.bailed && self.st == St::End;
        if !done || self.rows == 0 {
            self.staging.discard();
            return FastResult::Fallback(std::mem::take(&mut self.raw));
        }
        let width = match self.row_kind {
            Some(RowKind::Scalar) => 1,
            Some(RowKind::Array(w)) => w,
            None => {
                self.staging.discard();
                return FastResult::Fallback(std::mem::take(&mut self.raw));
            }
        };
        debug_assert_eq!(self.staging.len, self.rows * width);
        let storage = match self.staging.buf.take() {
            Some(buf) => buf,
            None => {
                return FastResult::Fallback(std::mem::take(&mut self.raw));
            }
        };
        match Tensor::from_shared(vec![self.rows, width], storage, 0) {
            Ok(tensor) => FastResult::Parsed(PredictBody {
                signature: self.signature.take().unwrap_or_default(),
                inputs: vec![(String::new(), tensor)],
                row_format: true,
            }),
            Err(_) => FastResult::Fallback(std::mem::take(&mut self.raw)),
        }
    }

    fn bail(&mut self) {
        self.bailed = true;
        self.staging.discard();
    }

    /// Stage one element, bailing once the count passes the element
    /// cap (the scalar path then reports the canonical limit error —
    /// or a shape error, whichever it hits first).
    #[inline]
    fn push_elem(&mut self, v: f64) -> bool {
        if self.staging.len >= MAX_TENSOR_ELEMS {
            self.bail();
            return false;
        }
        self.staging.push(v as f32);
        true
    }

    fn advance(&mut self, eof: bool) {
        loop {
            self.cursor += skip_ws(&self.raw[self.cursor..]);
            if self.cursor == self.raw.len() {
                if eof && self.st != St::End {
                    self.bail();
                }
                return;
            }
            let b = self.raw[self.cursor];
            match self.st {
                St::Start => {
                    if b != b'{' {
                        return self.bail();
                    }
                    self.cursor += 1;
                    self.st = St::RootKey;
                }
                St::RootKey => {
                    if b != b'"' {
                        return self.bail();
                    }
                    match scan_simple_string(&self.raw[self.cursor..]) {
                        StrScan::NeedMore if !eof => return,
                        StrScan::Ok(content) => {
                            let key = &self.raw[self.cursor + 1..self.cursor + 1 + content];
                            self.pending_key = match key {
                                b"signature_name" if self.signature.is_none() => Key::Signature,
                                b"instances" if !self.saw_instances => Key::Instances,
                                _ => return self.bail(),
                            };
                            self.cursor += content + 2;
                            self.st = St::RootColon;
                        }
                        _ => return self.bail(),
                    }
                }
                St::RootColon => {
                    if b != b':' {
                        return self.bail();
                    }
                    self.cursor += 1;
                    self.st = St::RootValue;
                }
                St::RootValue => match self.pending_key {
                    Key::Signature => {
                        if b != b'"' {
                            return self.bail();
                        }
                        match scan_simple_string(&self.raw[self.cursor..]) {
                            StrScan::NeedMore if !eof => return,
                            StrScan::Ok(content) => {
                                let s = &self.raw[self.cursor + 1..self.cursor + 1 + content];
                                // Content is printable ASCII by construction.
                                self.signature =
                                    Some(String::from_utf8(s.to_vec()).expect("ascii"));
                                self.cursor += content + 2;
                                self.st = St::RootSep;
                            }
                            _ => return self.bail(),
                        }
                    }
                    Key::Instances => {
                        if b != b'[' {
                            return self.bail();
                        }
                        self.cursor += 1;
                        self.saw_instances = true;
                        self.st = St::Row;
                    }
                },
                St::Row => match b {
                    b'[' => {
                        if self.row_kind == Some(RowKind::Scalar) {
                            return self.bail();
                        }
                        self.cursor += 1;
                        self.row_pos = 0;
                        self.st = St::ArrFirst;
                    }
                    b'-' | b'0'..=b'9' => {
                        if matches!(self.row_kind, Some(RowKind::Array(_))) {
                            return self.bail();
                        }
                        match parse_number_at(&self.raw[self.cursor..], eof) {
                            NumScan::NeedMore => return,
                            NumScan::Bail => return self.bail(),
                            NumScan::Ok(v, len) => {
                                if !self.push_elem(v) {
                                    return;
                                }
                                self.cursor += len;
                                self.row_kind = Some(RowKind::Scalar);
                                self.rows += 1;
                                self.st = St::RowSep;
                            }
                        }
                    }
                    // `]` here means an empty instances array; objects,
                    // strings and literals are scalar-codec territory.
                    _ => return self.bail(),
                },
                St::RowSep => match b {
                    b',' => {
                        self.cursor += 1;
                        self.st = St::Row;
                    }
                    b']' => {
                        self.cursor += 1;
                        self.st = St::RootSep;
                    }
                    _ => return self.bail(),
                },
                St::ArrFirst | St::ArrVal => match b {
                    b']' if self.st == St::ArrFirst => {
                        // Zero-width row: let the scalar path rule.
                        return self.bail();
                    }
                    b'-' | b'0'..=b'9' => {
                        match parse_number_at(&self.raw[self.cursor..], eof) {
                            NumScan::NeedMore => return,
                            NumScan::Bail => return self.bail(),
                            NumScan::Ok(v, len) => {
                                if !self.push_elem(v) {
                                    return;
                                }
                                self.cursor += len;
                                self.row_pos += 1;
                                self.st = St::ArrSep;
                            }
                        }
                    }
                    _ => return self.bail(),
                },
                St::ArrSep => match b {
                    b',' => {
                        self.cursor += 1;
                        self.st = St::ArrVal;
                    }
                    b']' => {
                        match self.row_kind {
                            None => self.row_kind = Some(RowKind::Array(self.row_pos)),
                            Some(RowKind::Array(w)) if w == self.row_pos => {}
                            // Width mismatch: the scalar codec owns the
                            // canonical "instance i has …" error.
                            _ => return self.bail(),
                        }
                        self.cursor += 1;
                        self.rows += 1;
                        self.st = St::RowSep;
                    }
                    _ => return self.bail(),
                },
                St::RootSep => match b {
                    b',' => {
                        self.cursor += 1;
                        self.st = St::RootKey;
                    }
                    b'}' => {
                        self.cursor += 1;
                        self.st = St::End;
                    }
                    _ => return self.bail(),
                },
                St::End => {
                    // Non-whitespace after the root object.
                    return self.bail();
                }
            }
        }
    }
}

/// One-shot fast parse of a whole body (the non-chunked ingress path,
/// benches, and the differential fuzz harness).
pub fn parse_predict_fast(body: &[u8]) -> FastResult {
    let mut p = FastPredictParser::new();
    p.feed(body);
    p.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::codec::parse_predict_body;
    use crate::util::pool::size_class;

    #[test]
    fn swar_digit_primitives() {
        assert!(is_eight_digits(u64::from_le_bytes(*b"12345678")));
        assert!(!is_eight_digits(u64::from_le_bytes(*b"1234567a")));
        assert!(!is_eight_digits(u64::from_le_bytes(*b".2345678")));
        assert_eq!(parse_eight_digits(u64::from_le_bytes(*b"12345678")), 12345678);
        assert_eq!(parse_eight_digits(u64::from_le_bytes(*b"00000000")), 0);
        assert_eq!(parse_eight_digits(u64::from_le_bytes(*b"99999999")), 99999999);
    }

    #[test]
    fn skip_digits_all_tiers_agree_with_naive() {
        let cases: Vec<Vec<u8>> = vec![
            b"".to_vec(),
            b"5".to_vec(),
            b"123,".to_vec(),
            b"1234567890123456789012345678901234567890]".to_vec(),
            vec![b'7'; 100],
            {
                let mut v = vec![b'3'; 37];
                v.push(0xff);
                v.extend_from_slice(b"123");
                v
            },
        ];
        for case in &cases {
            let naive = case.iter().take_while(|b| b.is_ascii_digit()).count();
            assert_eq!(skip_digits(case), naive, "{case:?}");
            assert_eq!(swar_skip_digits(case), naive, "{case:?}");
            #[cfg(target_arch = "x86_64")]
            assert_eq!(sse2_skip_digits(case), naive, "{case:?}");
        }
        // Every suffix of a long mixed string, to sweep alignments.
        let long = b"123456789012345678901234567890123456789.5e12,next";
        for start in 0..long.len() {
            let s = &long[start..];
            let naive = s.iter().take_while(|b| b.is_ascii_digit()).count();
            assert_eq!(skip_digits(s), naive, "start={start}");
        }
    }

    #[test]
    fn level_probe_is_stable() {
        let a = simd_level();
        let b = simd_level();
        assert_eq!(a, b);
        assert!(!a.name().is_empty());
    }

    fn assert_parses_hot(body: &[u8]) {
        let scalar = parse_predict_body(body).expect("scalar parse");
        match parse_predict_fast(body) {
            FastResult::Parsed(fast) => {
                assert_eq!(fast.signature, scalar.signature, "{body:?}");
                assert_eq!(fast.row_format, scalar.row_format);
                assert_eq!(fast.inputs.len(), scalar.inputs.len());
                let (fname, ft) = &fast.inputs[0];
                let (sname, st) = &scalar.inputs[0];
                assert_eq!(fname, sname);
                assert_eq!(ft.shape(), st.shape(), "{body:?}");
                let fb: Vec<u32> = ft.data().iter().map(|v| v.to_bits()).collect();
                let sb: Vec<u32> = st.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(fb, sb, "{body:?}");
                // Zero-copy finish: the staged pool buffer *is* the
                // tensor storage, at the class the scalar path uses.
                assert_eq!(ft.storage().len(), size_class(ft.len()), "{body:?}");
                assert_eq!(ft.data().as_ptr(), ft.storage().as_ptr());
            }
            FastResult::Fallback(_) => panic!("hot body bailed: {:?}", String::from_utf8_lossy(body)),
        }
    }

    #[test]
    fn hot_bodies_complete_and_match_scalar() {
        assert_parses_hot(br#"{"instances": [[1, 2, 3], [4, 5, 6]]}"#);
        assert_parses_hot(br#"{"instances": [1.5, 2.5, -0.25]}"#);
        assert_parses_hot(br#"{"instances":[[0.1,0.2],[0.3,1e-3]],"signature_name":"s"}"#);
        assert_parses_hot(br#"{"signature_name": "serving_default", "instances": [[-7]]}"#);
        assert_parses_hot(b"{ \"instances\" : [ [ 1.25 , 2.5 ] , [ 3.5 , 4.75 ] ] }\r\n");
        assert_parses_hot(br#"{"instances": [[-0], [0]]}"#);
        assert_parses_hot(br#"{"instances": [[1e22], [1e-22]]}"#);
        // Wide row exercising the 8-digit SWAR blocks.
        let wide: Vec<String> = (0..100).map(|i| format!("{}", i * 987654321u64)).collect();
        let body = format!(r#"{{"instances": [[{}]]}}"#, wide.join(","));
        assert_parses_hot(body.as_bytes());
    }

    #[test]
    fn odd_spellings_still_match_scalar_or_bail() {
        // Tokens outside the Clinger window or with odd spellings must
        // still match the scalar parse bit for bit when they complete.
        for body in [
            &br#"{"instances": [[9007199254740993]]}"#[..],
            br#"{"instances": [[12345678901234567890123]]}"#,
            br#"{"instances": [[1e308], [1e-308]]}"#,
            br#"{"instances": [[1e999]]}"#,
            br#"{"instances": [[0.000000000000000000000000001]]}"#,
            br#"{"instances": [[1.], [01]]}"#,
            br#"{"instances": [[-.5]]}"#,
        ] {
            match parse_predict_fast(body) {
                FastResult::Parsed(fast) => {
                    let scalar = parse_predict_body(body).expect("scalar parse");
                    let fb: Vec<u32> =
                        fast.inputs[0].1.data().iter().map(|v| v.to_bits()).collect();
                    let sb: Vec<u32> =
                        scalar.inputs[0].1.data().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(fb, sb, "{body:?}");
                }
                FastResult::Fallback(raw) => assert_eq!(raw, body),
            }
        }
    }

    #[test]
    fn off_grammar_bodies_bail_whole() {
        for body in [
            // Valid for the scalar codec, outside the hot grammar.
            &br#"{"inputs": {"x": [[1, 2]]}}"#[..],
            br#"{"instances": [{"x": [1]}, {"x": [2]}]}"#,
            br#"{"instances": [[1]], "note": "extra"}"#,
            br#"{"signature_name": "a\nb", "instances": [[1]]}"#,
            "{\"signature_name\": \"h\u{00e9}\", \"instances\": [[1]]}".as_bytes(),
            // Errors for the scalar codec too.
            br#"{"instances": []}"#,
            br#"{"instances": [[1, 2], [3]]}"#,
            br#"{"instances": [[1], 2]}"#,
            br#"{"instances": [[1,]]}"#,
            br#"{"instances": [[+1]]}"#,
            br#"{"instances": [[1][2]]}"#,
            br#"{"instances": [[1]]"#,
            br#"[1, 2]"#,
            b"\xff\xfe",
            br#"{"instances": [[1]]} trailing"#,
            br#"{"instances": [[true]]}"#,
        ] {
            match parse_predict_fast(body) {
                FastResult::Fallback(raw) => assert_eq!(raw, body),
                FastResult::Parsed(_) => {
                    panic!("off-grammar body completed: {:?}", String::from_utf8_lossy(body))
                }
            }
        }
    }

    #[test]
    fn byte_at_a_time_feed_matches_whole_body() {
        let bodies = [
            &br#"{"instances": [[1.25, 3.5e-2], [-7, 0.125]], "signature_name": "sig"}"#[..],
            br#"{"instances": [12345678901, 2.5]}"#,
            br#"{"instances": [[1, 2], [3]]}"#,
            br#"{"inputs": [[1, 2]]}"#,
        ];
        for body in bodies {
            let whole_parsed = match parse_predict_fast(body) {
                FastResult::Parsed(p) => Some(p),
                FastResult::Fallback(_) => None,
            };
            let mut p = FastPredictParser::new();
            for &b in body.iter() {
                p.feed(&[b]);
            }
            match (p.finish(), whole_parsed) {
                (FastResult::Parsed(a), Some(b)) => {
                    assert_eq!(a.signature, b.signature);
                    assert_eq!(a.inputs[0].1.shape(), b.inputs[0].1.shape());
                    let ab: Vec<u32> = a.inputs[0].1.data().iter().map(|v| v.to_bits()).collect();
                    let bb: Vec<u32> = b.inputs[0].1.data().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(ab, bb);
                }
                (FastResult::Fallback(raw), None) => assert_eq!(raw, body),
                (FastResult::Parsed(_), None) => panic!("chunked parsed, whole bailed: {body:?}"),
                (FastResult::Fallback(_), Some(_)) => {
                    panic!("chunked bailed, whole parsed: {body:?}")
                }
            }
        }
    }

    #[test]
    fn staged_growth_across_classes() {
        // One wide row fills several SWAR blocks in a single array.
        let wide = format!(
            r#"{{"instances": [[{}]]}}"#,
            vec!["1"; 100].join(",")
        );
        assert!(matches!(parse_predict_fast(wide.as_bytes()), FastResult::Parsed(_)));
        // Growth across several classes stays exact.
        let n = 1000;
        let body = format!(
            r#"{{"instances": [{}]}}"#,
            (0..n).map(|i| format!("[{i}.5]")).collect::<Vec<_>>().join(",")
        );
        match parse_predict_fast(body.as_bytes()) {
            FastResult::Parsed(p) => {
                let t = &p.inputs[0].1;
                assert_eq!(t.shape(), &[n, 1]);
                assert_eq!(t.storage().len(), size_class(n));
                assert_eq!(t.data()[17], 17.5);
            }
            FastResult::Fallback(_) => panic!("staged growth body bailed"),
        }
    }
}
