//! JSON ⇄ wire-message translation for the REST gateway.
//!
//! Modeled on TF-Serving's REST payloads:
//!
//! * **row format** — `{"instances": [row, …]}`: one entry per batch
//!   row; a row is a number (shape `[n, 1]`), an array of numbers
//!   (shape `[n, d]`), or a one-entry `{input_name: row}` object.
//!   Replies come back as `{"predictions": [row, …]}`.
//! * **column format** — `{"inputs": {name: tensor} | tensor}` with
//!   tensors as (possibly nested, rectangular) number arrays. Replies
//!   come back as `{"outputs": {name: tensor}}`.
//! * `:classify` / `:regress` take `{"examples": [{feature: value}]}`
//!   and return `{"results": …}`.
//!
//! Hot-path property: instance rows decode **straight into pooled
//! [`BufferPool`] storage** ([`Tensor::try_build_with`]) — the same
//! buffers the serving layer's zero-copy batch assembly consumes and
//! [`crate::server::builder::ServerCore::handle`] recycles after
//! inference — so JSON ingress costs one parse plus exactly one
//! buffer write, never an intermediate `Vec<f32>`.

use crate::base::tensor::Tensor;
use crate::inference::example::{Example, Feature};
use crate::rpc::proto::{Response, VersionMetadata};
use crate::runtime::artifacts::{SignatureDef, TensorInfo};
use crate::runtime::pjrt::OutTensor;
use crate::util::json::Json;
use crate::util::pool::BufferPool;
use anyhow::{anyhow, bail, Result};

/// Cap on decoded tensor elements (64 MiB of f32 — the body cap). A
/// JSON body can *claim* a huge shape in a few hundred bytes (deep
/// nesting whose first spine multiplies out to terabytes); the
/// element count is bounded **before** any buffer is acquired so a
/// tiny request can never drive a giant allocation.
pub const MAX_TENSOR_ELEMS: usize = 16 << 20;

fn checked_elems(n: usize, width: usize) -> Result<usize> {
    match n.checked_mul(width) {
        Some(total) if total <= MAX_TENSOR_ELEMS => Ok(total),
        _ => bail!(
            "tensor of {n} x {width} elements exceeds the {MAX_TENSOR_ELEMS}-element limit"
        ),
    }
}

// ------------------------------------------------------------ parsing

/// A parsed `:predict` body.
pub struct PredictBody {
    pub signature: String,
    pub inputs: Vec<(String, Tensor)>,
    /// Row format ("instances") replies with "predictions"; column
    /// format ("inputs") replies with "outputs".
    pub row_format: bool,
}

/// A parsed `:classify` / `:regress` body.
pub struct ExamplesBody {
    pub signature: String,
    pub examples: Vec<Example>,
}

fn parse_root(body: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(body).map_err(|_| anyhow!("request body is not utf-8"))?;
    let v = Json::parse(text)?;
    if v.as_obj().is_none() {
        bail!("request body must be a JSON object");
    }
    Ok(v)
}

fn signature_name(root: &Json) -> Result<String> {
    match root.get("signature_name") {
        None => Ok(String::new()),
        Some(Json::Str(s)) => Ok(s.clone()),
        Some(_) => bail!("\"signature_name\" must be a string"),
    }
}

pub fn parse_predict_body(body: &[u8]) -> Result<PredictBody> {
    let root = parse_root(body)?;
    let signature = signature_name(&root)?;
    match (root.get("instances"), root.get("inputs")) {
        (Some(_), Some(_)) => {
            bail!("body carries both \"instances\" and \"inputs\" — use one format")
        }
        (Some(instances), None) => {
            let (name, tensor) = decode_instances(instances)?;
            Ok(PredictBody { signature, inputs: vec![(name, tensor)], row_format: true })
        }
        (None, Some(inputs)) => Ok(PredictBody {
            signature,
            inputs: decode_columns(inputs)?,
            row_format: false,
        }),
        (None, None) => {
            bail!("body must carry \"instances\" (row format) or \"inputs\" (column format)")
        }
    }
}

/// Row format: every instance must match the first one's shape; rows
/// are written straight into one pooled buffer.
fn decode_instances(instances: &Json) -> Result<(String, Tensor)> {
    let rows = instances
        .as_arr()
        .ok_or_else(|| anyhow!("\"instances\" must be an array"))?;
    if rows.is_empty() {
        bail!("\"instances\" is empty");
    }
    // Unwrap the optional one-entry {input_name: row} envelope.
    let name = match &rows[0] {
        Json::Obj(o) if o.len() == 1 => o.keys().next().unwrap().clone(),
        Json::Obj(o) => bail!("instance 0 must name exactly one input (has {})", o.len()),
        _ => String::new(),
    };
    let mut unwrapped: Vec<&Json> = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        if name.is_empty() {
            if row.as_obj().is_some() {
                bail!("instance {i} is an object but instance 0 was a bare row");
            }
            unwrapped.push(row);
        } else {
            match row.get(&name) {
                Some(v) if row.as_obj().unwrap().len() == 1 => unwrapped.push(v),
                _ => bail!("instance {i} does not name input '{name}' like instance 0"),
            }
        }
    }
    let (width, scalar) = match unwrapped[0] {
        Json::Num(_) => (1usize, true),
        Json::Arr(a) => (a.len(), false),
        _ => bail!("instance 0 must be a number or an array of numbers"),
    };
    let n = unwrapped.len();
    checked_elems(n, width)?;
    let tensor = Tensor::try_build_with(vec![n, width], &BufferPool::global(), |buf| {
        for (i, row) in unwrapped.iter().enumerate() {
            match row {
                Json::Num(x) if scalar => buf[i] = *x as f32,
                Json::Arr(xs) if !scalar => {
                    if xs.len() != width {
                        bail!(
                            "instance {i} has {} values, instance 0 has {width}",
                            xs.len()
                        );
                    }
                    for (j, x) in xs.iter().enumerate() {
                        buf[i * width + j] = x
                            .as_f64()
                            .ok_or_else(|| anyhow!("instance {i} holds a non-number"))?
                            as f32;
                    }
                }
                _ => bail!("instance {i} does not match instance 0's shape"),
            }
        }
        Ok(())
    })?;
    Ok((name, tensor))
}

/// Column format: `{name: tensor}` (named) or a bare tensor
/// (positional, binding the signature's sole input).
fn decode_columns(inputs: &Json) -> Result<Vec<(String, Tensor)>> {
    match inputs {
        Json::Obj(o) => {
            if o.is_empty() {
                bail!("\"inputs\" names no tensors");
            }
            o.iter()
                .map(|(k, v)| {
                    Ok((
                        k.clone(),
                        decode_tensor(v).map_err(|e| anyhow!("input '{k}': {e}"))?,
                    ))
                })
                .collect()
        }
        other => Ok(vec![(String::new(), decode_tensor(other)?)]),
    }
}

/// Nested-array → [`Tensor`]: the shape comes from the first spine of
/// the nesting (which the fill pass then enforces as rectangular), and
/// every number lands directly in one pooled buffer.
pub fn decode_tensor(v: &Json) -> Result<Tensor> {
    let mut shape = Vec::new();
    let mut cur = v;
    loop {
        match cur {
            Json::Arr(a) => {
                if shape.len() >= 8 {
                    bail!("tensor nesting deeper than rank 8");
                }
                shape.push(a.len());
                match a.first() {
                    Some(first) => cur = first,
                    None => break,
                }
            }
            Json::Num(_) => break,
            _ => bail!("tensor elements must be numbers"),
        }
    }
    if shape.is_empty() {
        bail!("tensor must be an array");
    }
    // Bound the claimed element count before acquiring any buffer —
    // the shape came from the first spine only and is untrusted.
    shape
        .iter()
        .try_fold(1usize, |acc, &d| checked_elems(acc, d.max(1)))?;
    Tensor::try_build_with(shape.clone(), &BufferPool::global(), |buf| {
        let mut idx = 0usize;
        fill_nested(v, &shape, 0, buf, &mut idx)
    })
}

fn fill_nested(
    v: &Json,
    shape: &[usize],
    depth: usize,
    buf: &mut [f32],
    idx: &mut usize,
) -> Result<()> {
    if depth == shape.len() {
        buf[*idx] = v
            .as_f64()
            .ok_or_else(|| anyhow!("tensor elements must be numbers"))? as f32;
        *idx += 1;
        return Ok(());
    }
    match v {
        Json::Arr(a) if a.len() == shape[depth] => {
            for e in a {
                fill_nested(e, shape, depth + 1, buf, idx)?;
            }
            Ok(())
        }
        Json::Arr(a) => bail!(
            "ragged tensor: {} elements at depth {depth}, want {}",
            a.len(),
            shape[depth]
        ),
        _ => bail!("ragged tensor nesting at depth {depth}"),
    }
}

pub fn parse_examples_body(body: &[u8]) -> Result<ExamplesBody> {
    let root = parse_root(body)?;
    let signature = signature_name(&root)?;
    let rows = root
        .get("examples")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("body must carry an \"examples\" array"))?;
    if rows.is_empty() {
        bail!("\"examples\" is empty");
    }
    let mut examples = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let obj = row
            .as_obj()
            .ok_or_else(|| anyhow!("example {i} must be a {{feature: value}} object"))?;
        let mut ex = Example::new();
        for (name, value) in obj {
            let feature = match value {
                Json::Num(x) => Feature::Floats(vec![*x as f32]),
                Json::Str(s) => Feature::Bytes(s.as_bytes().to_vec()),
                Json::Arr(xs) => {
                    let floats: Option<Vec<f32>> =
                        xs.iter().map(|x| x.as_f64().map(|v| v as f32)).collect();
                    match floats {
                        Some(f) => Feature::Floats(f),
                        None => bail!(
                            "example {i} feature '{name}' must be a flat number array"
                        ),
                    }
                }
                _ => bail!("example {i} feature '{name}' has an unsupported type"),
            };
            ex = ex.with(name, feature);
        }
        examples.push(ex);
    }
    Ok(ExamplesBody { signature, examples })
}

// ----------------------------------------------------------- encoding

fn num_u64(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Nested-array JSON of a numeric slice with the given shape (shared
/// by the f32 and i32 tensor paths).
fn nest<T: Copy + Into<f64>>(data: &[T], shape: &[usize]) -> Json {
    match shape.split_first() {
        None => data
            .first()
            .map(|x| Json::Num((*x).into()))
            .unwrap_or(Json::Null),
        Some((&d, rest)) if rest.is_empty() => {
            Json::Arr(data.iter().take(d).map(|x| Json::Num((*x).into())).collect())
        }
        Some((&d, rest)) => {
            let w: usize = rest.iter().product();
            Json::Arr(
                (0..d)
                    .map(|i| nest(&data[i * w..(i + 1) * w], rest))
                    .collect(),
            )
        }
    }
}

/// One batch row: rank-1 yields a scalar, higher ranks the row's
/// nested array.
fn nest_row<T: Copy + Into<f64>>(data: &[T], shape: &[usize], i: usize) -> Json {
    if shape.len() <= 1 {
        Json::Num(data[i].into())
    } else {
        let w: usize = shape[1..].iter().product();
        nest(&data[i * w..(i + 1) * w], &shape[1..])
    }
}

/// Full tensor as nested arrays.
fn out_tensor_json(t: &OutTensor) -> Json {
    match t {
        OutTensor::F32(t) => nest(t.data(), t.shape()),
        OutTensor::I32(t) => nest(t.data(), t.shape()),
    }
}

fn out_tensor_row_json(t: &OutTensor, i: usize) -> Json {
    match t {
        OutTensor::F32(t) => nest_row(t.data(), t.shape(), i),
        OutTensor::I32(t) => nest_row(t.data(), t.shape(), i),
    }
}

/// `:predict` reply. Row format: `predictions[i]` is row `i` — the
/// bare output row when the signature has one output, else a
/// `{output_name: row}` object. Column format: full tensors under
/// `"outputs"`.
pub fn predict_response_json(resp: &Response, row_format: bool) -> Result<Json> {
    let (version, outputs) = match resp {
        Response::Predict { model_version, outputs } => (*model_version, outputs),
        _ => bail!("predict produced an unexpected response variant"),
    };
    let payload = if row_format {
        let n = outputs.first().map(|(_, t)| t.batch()).unwrap_or(0);
        if let Some((name, t)) = outputs.iter().find(|(_, t)| t.batch() != n) {
            bail!(
                "output '{name}' has batch {} but the first output has {n} — \
                 column format (\"inputs\") reports per-output tensors",
                t.batch()
            );
        }
        let predictions: Vec<Json> = (0..n)
            .map(|i| {
                if outputs.len() == 1 {
                    out_tensor_row_json(&outputs[0].1, i)
                } else {
                    Json::Obj(
                        outputs
                            .iter()
                            .map(|(name, t)| (name.clone(), out_tensor_row_json(t, i)))
                            .collect(),
                    )
                }
            })
            .collect();
        ("predictions", Json::Arr(predictions))
    } else {
        (
            "outputs",
            Json::Obj(
                outputs
                    .iter()
                    .map(|(name, t)| (name.clone(), out_tensor_json(t)))
                    .collect(),
            ),
        )
    };
    Ok(Json::obj(vec![
        ("model_version", num_u64(version)),
        payload,
    ]))
}

/// `:classify` reply: `results[i]` lists `[class, log_prob]` pairs for
/// every class of example `i`; `classes[i]` is the argmax.
pub fn classify_response_json(
    model_version: u64,
    classes: &[i32],
    log_probs: &[Vec<f32>],
) -> Json {
    let results: Vec<Json> = log_probs
        .iter()
        .map(|row| {
            Json::Arr(
                row.iter()
                    .enumerate()
                    .map(|(c, lp)| {
                        Json::Arr(vec![Json::Num(c as f64), Json::Num(*lp as f64)])
                    })
                    .collect(),
            )
        })
        .collect();
    Json::obj(vec![
        ("model_version", num_u64(model_version)),
        ("classes", Json::Arr(classes.iter().map(|c| Json::Num(*c as f64)).collect())),
        ("results", Json::Arr(results)),
    ])
}

/// `:regress` reply: one value per example.
pub fn regress_response_json(model_version: u64, values: &[f32]) -> Json {
    Json::obj(vec![
        ("model_version", num_u64(model_version)),
        ("results", Json::Arr(values.iter().map(|v| Json::Num(*v as f64)).collect())),
    ])
}

fn tensor_info_json(info: &TensorInfo) -> Json {
    Json::obj(vec![
        ("name", Json::str(&info.name)),
        ("dtype", Json::str(&info.dtype)),
        (
            "shape",
            Json::Arr(info.shape.iter().map(|d| Json::Num(*d as f64)).collect()),
        ),
    ])
}

fn signature_json(def: &SignatureDef) -> Json {
    Json::obj(vec![
        ("method", Json::str(&def.method)),
        ("inputs", Json::Arr(def.inputs.iter().map(tensor_info_json).collect())),
        ("outputs", Json::Arr(def.outputs.iter().map(tensor_info_json).collect())),
    ])
}

/// `GET /v1/models/...` reply: per-version state, labels and signature
/// defs — the REST mirror of `GetModelMetadata`.
pub fn metadata_json(model: &str, versions: &[VersionMetadata]) -> Json {
    let versions: Vec<Json> = versions
        .iter()
        .map(|vm| {
            Json::obj(vec![
                ("version", num_u64(vm.version)),
                ("state", Json::str(&vm.state)),
                (
                    "labels",
                    Json::Arr(vm.labels.iter().map(Json::str).collect()),
                ),
                (
                    "signatures",
                    Json::Obj(
                        vm.signatures
                            .iter()
                            .map(|(name, def)| (name.clone(), signature_json(def)))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("model", Json::str(model)),
        ("versions", Json::Arr(versions)),
    ])
}

/// `GET /v1/models` reply: every model the server holds, with
/// per-version state and labels (no signatures — the listing is the
/// fleet inventory; drill into `/v1/models/{name}` for defs). A model
/// the fleet rollout engine has touched additionally carries a
/// `rollout_status` string (phase, or the auto-rollback reason).
pub fn models_list_json(
    models: &[(String, Vec<(u64, String, Vec<String>)>, Option<String>)],
) -> Json {
    let models: Vec<Json> = models
        .iter()
        .map(|(name, versions, rollout)| {
            let mut fields = vec![
                ("name", Json::str(name)),
                (
                    "versions",
                    Json::Arr(
                        versions
                            .iter()
                            .map(|(version, state, labels)| {
                                Json::obj(vec![
                                    ("version", num_u64(*version)),
                                    ("state", Json::str(state)),
                                    (
                                        "labels",
                                        Json::Arr(labels.iter().map(Json::str).collect()),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ];
            if let Some(status) = rollout {
                fields.push(("rollout_status", Json::str(status)));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![("models", Json::Arr(models))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::tensor::TensorI32;
    use crate::util::pool::size_class;

    #[test]
    fn row_format_decodes_into_pooled_storage() {
        let body = br#"{"instances": [[1, 2, 3], [4, 5, 6]]}"#;
        let parsed = parse_predict_body(body).unwrap();
        assert!(parsed.row_format);
        assert_eq!(parsed.signature, "");
        let (name, t) = &parsed.inputs[0];
        assert_eq!(name, "");
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // The decode wrote into a size-class pool buffer at offset 0 —
        // exactly what the serving layer recycles after inference.
        assert_eq!(t.storage().len(), size_class(6));
        assert_eq!(t.data().as_ptr(), t.storage().as_ptr());
    }

    #[test]
    fn row_format_named_and_scalar_instances() {
        let parsed =
            parse_predict_body(br#"{"instances": [{"x": [1, 2]}, {"x": [3, 4]}], "signature_name": "s"}"#)
                .unwrap();
        assert_eq!(parsed.signature, "s");
        assert_eq!(parsed.inputs[0].0, "x");
        assert_eq!(parsed.inputs[0].1.shape(), &[2, 2]);

        // Scalar instances become a [n, 1] tensor.
        let parsed = parse_predict_body(br#"{"instances": [1.5, 2.5]}"#).unwrap();
        assert_eq!(parsed.inputs[0].1.shape(), &[2, 1]);
        assert_eq!(parsed.inputs[0].1.data(), &[1.5, 2.5]);
    }

    #[test]
    fn row_format_rejects_bad_bodies() {
        for (body, needle) in [
            (&br#"{"instances": []}"#[..], "empty"),
            (br#"{"instances": [[1, 2], [3]]}"#, "instance 1"),
            (br#"{"instances": [[1], "x"]}"#, "instance 1"),
            (br#"{"instances": [{"x": [1]}, {"y": [1]}]}"#, "instance 1"),
            (br#"{"instances": [{"x": [1], "y": [2]}]}"#, "exactly one"),
            (br#"{"instances": [[1, "a"]]}"#, "non-number"),
            (br#"{"instances": 5}"#, "array"),
            (br#"{"inputs": {"x": [1]}, "instances": [[1]]}"#, "both"),
            (br#"{}"#, "must carry"),
            (br#"[1]"#, "object"),
            (b"\xff\xfe", "utf-8"),
            (br#"{"instances": [[1]], "signature_name": 3}"#, "signature_name"),
        ] {
            let err = parse_predict_body(body).unwrap_err().to_string();
            assert!(err.contains(needle), "{body:?} → {err}");
        }
    }

    #[test]
    fn column_format_decodes_named_tensors() {
        let parsed =
            parse_predict_body(br#"{"inputs": {"x": [[1, 2], [3, 4], [5, 6]]}}"#).unwrap();
        assert!(!parsed.row_format);
        let (name, t) = &parsed.inputs[0];
        assert_eq!(name, "x");
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.storage().len(), size_class(6));

        // Bare tensor binds positionally.
        let parsed = parse_predict_body(br#"{"inputs": [[1, 2]]}"#).unwrap();
        assert_eq!(parsed.inputs[0].0, "");
        assert_eq!(parsed.inputs[0].1.shape(), &[1, 2]);
    }

    #[test]
    fn column_format_rejects_ragged_and_deep() {
        let err = parse_predict_body(br#"{"inputs": {"x": [[1, 2], [3]]}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("ragged") && err.contains("'x'"), "{err}");
        let err = parse_predict_body(br#"{"inputs": {"x": [[1], 2]}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("ragged"), "{err}");
        // Rank > 8 rejected before any allocation.
        let deep = format!(r#"{{"inputs": {{"x": {}1{}}}}}"#, "[".repeat(9), "]".repeat(9));
        assert!(parse_predict_body(deep.as_bytes()).is_err());
        let err = parse_predict_body(br#"{"inputs": {}}"#).unwrap_err().to_string();
        assert!(err.contains("no tensors"), "{err}");
    }

    #[test]
    fn claimed_giant_shapes_rejected_before_allocation() {
        // A small JSON body must never drive a giant zeroed
        // allocation. Column format: the shape comes from the first
        // spine, so only the first child of each level needs depth —
        // ~4 KB of JSON claims [32; 8] ≈ 1.1e12 elements.
        let mut t = format!("[{}]", vec!["1"; 32].join(","));
        for _ in 0..7 {
            t = format!("[{},{}]", t, vec!["0"; 31].join(","));
        }
        let body = format!(r#"{{"inputs": {{"x": {t}}}}}"#);
        assert!(body.len() < 16 << 10, "test body unexpectedly large");
        let err = parse_predict_body(body.as_bytes()).unwrap_err().to_string();
        assert!(err.contains("element limit"), "{err}");
        // Row format: width comes from instance 0, so one wide row
        // plus many tiny ones claims n × width before any row-length
        // validation could trip.
        let wide = format!("[{}]", vec!["1"; 100_000].join(","));
        let body = format!(
            r#"{{"instances": [{wide},{}]}}"#,
            vec!["[1]"; 199].join(",")
        );
        let err = parse_predict_body(body.as_bytes()).unwrap_err().to_string();
        assert!(err.contains("element limit"), "{err}");
    }

    #[test]
    fn examples_body_decodes_features() {
        let parsed = parse_examples_body(
            br#"{"examples": [{"x": [1, 2], "tag": "a"}, {"x": 3}], "signature_name": "classify"}"#,
        )
        .unwrap();
        assert_eq!(parsed.signature, "classify");
        assert_eq!(parsed.examples.len(), 2);
        assert_eq!(parsed.examples[0].floats("x").unwrap(), &[1.0, 2.0]);
        assert_eq!(parsed.examples[1].floats("x").unwrap(), &[3.0]);
        for (body, needle) in [
            (&br#"{"examples": []}"#[..], "empty"),
            (br#"{"examples": [5]}"#, "object"),
            (br#"{"examples": [{"x": [[1]]}]}"#, "flat number array"),
            (br#"{"examples": [{"x": null}]}"#, "unsupported"),
            (br#"{}"#, "examples"),
        ] {
            let err = parse_examples_body(body).unwrap_err().to_string();
            assert!(err.contains(needle), "{body:?} → {err}");
        }
    }

    #[test]
    fn predict_response_row_and_column_shapes() {
        let resp = Response::Predict {
            model_version: 2,
            outputs: vec![
                (
                    "log_probs".into(),
                    OutTensor::F32(
                        Tensor::matrix(vec![vec![-0.5, -1.0], vec![-0.25, -2.0]]).unwrap(),
                    ),
                ),
                (
                    "class".into(),
                    OutTensor::I32(TensorI32::new(vec![2], vec![0, 1]).unwrap()),
                ),
            ],
        };
        // Row format: one {name: row} object per instance.
        let json = predict_response_json(&resp, true).unwrap();
        assert_eq!(json.get("model_version").unwrap().as_u64(), Some(2));
        let preds = json.get("predictions").unwrap().as_arr().unwrap();
        assert_eq!(preds.len(), 2);
        assert_eq!(preds[1].get("class").unwrap().as_i64(), Some(1));
        assert_eq!(
            preds[0].get("log_probs").unwrap(),
            &Json::Arr(vec![Json::Num(-0.5), Json::Num(-1.0)])
        );
        // Column format: full tensors under "outputs".
        let json = predict_response_json(&resp, false).unwrap();
        let outs = json.get("outputs").unwrap();
        assert_eq!(
            outs.get("class").unwrap(),
            &Json::Arr(vec![Json::Num(0.0), Json::Num(1.0)])
        );
        assert_eq!(
            outs.get("log_probs").unwrap().as_arr().unwrap().len(),
            2
        );

        // Single output in row format: bare rows, no object wrapper.
        let solo = Response::Predict {
            model_version: 1,
            outputs: vec![(
                "value".into(),
                OutTensor::F32(Tensor::vec(vec![0.5, 1.5])),
            )],
        };
        let json = predict_response_json(&solo, true).unwrap();
        assert_eq!(
            json.get("predictions").unwrap(),
            &Json::Arr(vec![Json::Num(0.5), Json::Num(1.5)])
        );
    }

    #[test]
    fn classify_regress_and_metadata_json() {
        // Dyadic values only: f32 → f64 widening must stay exact for
        // the equality below.
        let json = classify_response_json(3, &[1, 0], &[vec![-1.0, -0.25], vec![-0.5, -2.0]]);
        assert_eq!(json.get("model_version").unwrap().as_u64(), Some(3));
        let results = json.get("results").unwrap().as_arr().unwrap();
        assert_eq!(
            results[0].as_arr().unwrap()[1],
            Json::Arr(vec![Json::Num(1.0), Json::Num(-0.25)])
        );
        let json = regress_response_json(1, &[0.25]);
        assert_eq!(json.get("results").unwrap(), &Json::Arr(vec![Json::Num(0.25)]));

        let spec = crate::runtime::artifacts::ArtifactSpec::synthetic_multi_head("syn", 2, 8, 3);
        let vm = VersionMetadata {
            version: 2,
            state: "ready".into(),
            labels: vec!["canary".into()],
            signatures: spec.signatures.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
        };
        let json = metadata_json("syn", &[vm]);
        assert_eq!(json.get("model").unwrap().as_str(), Some("syn"));
        let v = &json.get("versions").unwrap().as_arr().unwrap()[0];
        assert_eq!(v.get("state").unwrap().as_str(), Some("ready"));
        assert_eq!(
            v.get("labels").unwrap(),
            &Json::Arr(vec![Json::str("canary")])
        );
        let sig = v.get_path("signatures.regress").unwrap();
        assert_eq!(sig.get("method").unwrap().as_str(), Some("regress"));
        assert_eq!(
            sig.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap(),
            &Json::Arr(vec![Json::Num(-1.0), Json::Num(8.0)])
        );
        // The whole reply serializes to parseable JSON.
        assert!(Json::parse(&json.to_string()).is_ok());
    }

    #[test]
    fn models_list_carries_rollout_status_only_when_present() {
        let models = vec![
            (
                "plain".to_string(),
                vec![(1u64, "ready".to_string(), vec![])],
                None,
            ),
            (
                "rolling".to_string(),
                vec![
                    (1u64, "ready".to_string(), vec!["stable".to_string()]),
                    (2u64, "ready".to_string(), vec!["canary".to_string()]),
                ],
                Some("rolled_back: error-rate 0.41 > 0.10".to_string()),
            ),
        ];
        let json = models_list_json(&models);
        let arr = json.get("models").unwrap().as_arr().unwrap();
        // Untouched models omit the key entirely.
        assert!(arr[0].get("rollout_status").is_none());
        assert_eq!(
            arr[1].get("rollout_status").unwrap().as_str(),
            Some("rolled_back: error-rate 0.41 > 0.10")
        );
        assert_eq!(
            arr[1].get("versions").unwrap().as_arr().unwrap()[1]
                .get("labels")
                .unwrap(),
            &Json::Arr(vec![Json::str("canary")])
        );
        assert!(Json::parse(&json.to_string()).is_ok());
    }

    #[test]
    fn non_finite_outputs_stay_valid_json() {
        let resp = Response::Predict {
            model_version: 1,
            outputs: vec![(
                "y".into(),
                OutTensor::F32(Tensor::vec(vec![f32::NAN, 1.0])),
            )],
        };
        let json = predict_response_json(&resp, true).unwrap();
        let text = json.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.get("predictions").unwrap(),
            &Json::Arr(vec![Json::Null, Json::Num(1.0)])
        );
    }
}
