//! REST routing: TF-Serving-shaped URLs dispatched through the same
//! [`ServerCore::handle`] the binary RPC server uses, so signatures,
//! version labels, batching and lifecycle behave identically on both
//! planes.
//!
//! ```text
//! POST   /v1/models/{name}[/versions/{v}|/labels/{l}]:predict
//! POST   /v1/models/{name}[/versions/{v}|/labels/{l}]:classify
//! POST   /v1/models/{name}[/versions/{v}|/labels/{l}]:regress
//! GET    /v1/models/{name}[/versions/{v}|/labels/{l}]     (metadata)
//! DELETE /v1/models/{name}/labels/{l}                     (drop label)
//! GET    /healthz
//! GET    /metrics
//! ```
//!
//! Errors use one envelope, `{"error": "..."}`. Status codes map
//! structurally from the core's typed [`ErrorKind`]: lookup failures
//! (unknown model/version/label) are 404, validation failures (shape,
//! signature, conflicting spec) are 400, retryable refusals (version
//! unloading mid-request, load shedding, drain) are 503 with a
//! `Retry-After` hint, and expired per-request deadlines are 504.
//! Errors without a kind are server faults (500), except lookup-shaped
//! messages, which the legacy substring table still rescues to 404.
//!
//! Data-plane POSTs honor an `X-Request-Deadline-Ms` header: the whole
//! request (queueing included) must finish within that many
//! milliseconds of arrival or it is dropped before execution.

use super::codec;
use super::expose;
use super::server::{HttpHandler, HttpRequest, HttpResponse};
use crate::base::error::ErrorKind;
use crate::inference::ModelSpec;
use crate::rpc::proto::{Request, Response};
use crate::server::builder::ServerCore;
use crate::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

/// Data-plane verb carried as a `:suffix` on the model path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Verb {
    Predict,
    Classify,
    Regress,
}

/// A parsed `/v1/models/...` URL.
#[derive(Debug, PartialEq)]
pub(crate) struct Route {
    pub spec: ModelSpec,
    pub verb: Option<Verb>,
}

/// Build the gateway's request handler over a shared [`ServerCore`].
pub fn gateway(core: Arc<ServerCore>) -> HttpHandler {
    Arc::new(move |req: &HttpRequest| {
        let t0 = Instant::now();
        let resp = route(&core, req);
        core.registry.counter("http.requests").inc();
        if resp.status >= 400 {
            core.registry.counter("http.errors").inc();
        }
        core.registry
            .histogram("http.latency_ns")
            .record_duration(t0.elapsed());
        resp
    })
}

fn route(core: &ServerCore, req: &HttpRequest) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => HttpResponse::text(200, "ok\n"),
        ("GET", "/metrics") => HttpResponse::text(200, &expose::metrics_text(core)),
        ("GET", "/v1/models") => models_list(core),
        _ if req.path.starts_with("/v1/models/") => models_route(core, req),
        (method, path) => {
            HttpResponse::error(404, &format!("no route for {method} {path}"))
        }
    }
}

fn models_route(core: &ServerCore, req: &HttpRequest) -> HttpResponse {
    let route = match parse_model_path(&req.path) {
        Ok(r) => r,
        Err((status, message)) => return HttpResponse::error(status, &message),
    };
    match (req.method.as_str(), route.verb) {
        ("POST", Some(verb)) => {
            let deadline_ms = match deadline_of(req) {
                Ok(d) => d,
                Err(resp) => return resp,
            };
            data_plane(core, &req.body, route.spec, verb, deadline_ms)
        }
        ("GET", None) => metadata(core, route.spec),
        ("DELETE", None) if route.spec.label.is_some() => delete_label(core, route.spec),
        ("POST", None) => HttpResponse::error(
            400,
            "POST requires a :predict, :classify or :regress suffix",
        ),
        (method, _) => HttpResponse::error(
            405,
            &format!("method {method} not allowed for {}", req.path),
        ),
    }
}

/// Parse `/v1/models/{name}[/versions/{v}|/labels/{l}]` with an
/// optional `:verb` suffix. Errors carry the HTTP status to answer.
pub(crate) fn parse_model_path(path: &str) -> Result<Route, (u16, String)> {
    let rest = path
        .strip_prefix("/v1/models/")
        .ok_or_else(|| (404, format!("no route for {path}")))?;
    let (target, verb) = match rest.rsplit_once(':') {
        Some((t, v)) => {
            let verb = match v {
                "predict" => Verb::Predict,
                "classify" => Verb::Classify,
                "regress" => Verb::Regress,
                other => return Err((400, format!("unknown method ':{other}'"))),
            };
            (t, Some(verb))
        }
        None => (rest, None),
    };
    let segments: Option<Vec<String>> = target.split('/').map(percent_decode).collect();
    let segments = segments.ok_or_else(|| (400, format!("bad percent-encoding in {path}")))?;
    let spec = match segments.as_slice() {
        [name] if !name.is_empty() => ModelSpec::latest(name.clone()),
        [name, kind, version] if kind.as_str() == "versions" && !name.is_empty() => {
            let v: u64 = version
                .parse()
                .map_err(|_| (400, format!("bad version number {version:?}")))?;
            ModelSpec::at_version(name.clone(), v)
        }
        [name, kind, label]
            if kind.as_str() == "labels" && !name.is_empty() && !label.is_empty() =>
        {
            ModelSpec::with_label(name.clone(), label.clone())
        }
        _ => return Err((404, format!("no route for {path}"))),
    };
    Ok(Route { spec, verb })
}

fn percent_decode(s: &str) -> Option<String> {
    if !s.contains('%') {
        return Some(s.to_string());
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            let hex = bytes.get(i + 1..i + 3)?;
            let v = u8::from_str_radix(std::str::from_utf8(hex).ok()?, 16).ok()?;
            out.push(v);
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).ok()
}

/// HTTP status for a typed core error. The kind decides structurally:
/// `NotFound` → 404, `InvalidArgument` → 400, `FailedPrecondition`
/// (unload races, load shedding — retryable) → 503. `Internal` means
/// the error never got a kind: lookup-shaped messages are rescued to
/// 404 by the legacy substring table, and everything else is what it
/// says — a server fault, 500 (request-caused rejections all carry
/// `InvalidArgument` at their creation site now).
fn error_status(kind: ErrorKind, message: &str) -> u16 {
    match kind {
        ErrorKind::NotFound => 404,
        ErrorKind::InvalidArgument => 400,
        ErrorKind::FailedPrecondition => 503,
        ErrorKind::Unavailable => 503,
        ErrorKind::DeadlineExceeded => 504,
        ErrorKind::Internal => {
            const NOT_FOUND: [&str; 4] =
                ["not found", "no ready versions", "not ready", "no version"];
            if NOT_FOUND.iter().any(|n| message.contains(n)) {
                404
            } else {
                500
            }
        }
    }
}

fn core_error(core: &ServerCore, kind: ErrorKind, message: &str) -> HttpResponse {
    let status = error_status(kind, message);
    let resp = HttpResponse::error(status, message);
    if status == 503 {
        // Retryable refusal: tell well-behaved clients when to come
        // back instead of letting them hammer an overloaded server.
        resp.with_header("Retry-After", core.admission.retry_after_secs().to_string())
    } else {
        resp
    }
}

/// Per-request deadline from the `X-Request-Deadline-Ms` header.
fn deadline_of(req: &HttpRequest) -> Result<Option<u64>, HttpResponse> {
    match req.header("x-request-deadline-ms") {
        None => Ok(None),
        Some(v) => v.trim().parse::<u64>().map(Some).map_err(|_| {
            HttpResponse::error(400, &format!("bad X-Request-Deadline-Ms value {v:?}"))
        }),
    }
}

/// Wrap a core request in the deadline envelope when the header asked
/// for one (the core unwraps it into `RunOptions`).
fn with_deadline(req: Request, deadline_ms: Option<u64>) -> Request {
    match deadline_ms {
        Some(ms) => req.with_deadline_ms(ms),
        None => req,
    }
}

fn data_plane(
    core: &ServerCore,
    body: &[u8],
    spec: ModelSpec,
    verb: Verb,
    deadline_ms: Option<u64>,
) -> HttpResponse {
    match verb {
        Verb::Predict => {
            let parsed = match codec::parse_predict_body(body) {
                Ok(p) => p,
                Err(e) => return HttpResponse::error(400, &e.to_string()),
            };
            let row_format = parsed.row_format;
            let resp = core.handle(with_deadline(
                Request::Predict {
                    spec,
                    signature: parsed.signature,
                    inputs: parsed.inputs,
                },
                deadline_ms,
            ));
            if let Response::Error { kind, message } = &resp {
                return core_error(core, *kind, message);
            }
            if !matches!(resp, Response::Predict { .. }) {
                return HttpResponse::error(500, &format!("unexpected response {resp:?}"));
            }
            let result = match codec::predict_response_json(&resp, row_format) {
                Ok(json) => HttpResponse::json(200, &json),
                Err(e) => HttpResponse::error(500, &e.to_string()),
            };
            // JSON is built; sole-owner output storage goes back to
            // the pools, same as the RPC reply path.
            resp.recycle_buffers();
            result
        }
        Verb::Classify => {
            let parsed = match codec::parse_examples_body(body) {
                Ok(p) => p,
                Err(e) => return HttpResponse::error(400, &e.to_string()),
            };
            match core.handle(with_deadline(
                Request::Classify {
                    spec,
                    signature: parsed.signature,
                    examples: parsed.examples,
                },
                deadline_ms,
            )) {
                Response::Classify { model_version, classes, log_probs } => HttpResponse::json(
                    200,
                    &codec::classify_response_json(model_version, &classes, &log_probs),
                ),
                Response::Error { kind, message } => core_error(core, kind, &message),
                other => HttpResponse::error(500, &format!("unexpected response {other:?}")),
            }
        }
        Verb::Regress => {
            let parsed = match codec::parse_examples_body(body) {
                Ok(p) => p,
                Err(e) => return HttpResponse::error(400, &e.to_string()),
            };
            match core.handle(with_deadline(
                Request::Regress {
                    spec,
                    signature: parsed.signature,
                    examples: parsed.examples,
                },
                deadline_ms,
            )) {
                Response::Regress { model_version, values } => HttpResponse::json(
                    200,
                    &codec::regress_response_json(model_version, &values),
                ),
                Response::Error { kind, message } => core_error(core, kind, &message),
                other => HttpResponse::error(500, &format!("unexpected response {other:?}")),
            }
        }
    }
}

/// `GET /v1/models`: fleet inventory — every model the server holds,
/// with per-version state and labels, from the lifecycle monitor.
fn models_list(core: &ServerCore) -> HttpResponse {
    let mut by_model: std::collections::BTreeMap<String, Vec<(u64, String, Vec<String>)>> =
        Default::default();
    for (id, state) in core.avm().monitor().snapshot() {
        let labels = core.labels.labels_of_version(&id.name, id.version);
        by_model
            .entry(id.name)
            .or_default()
            .push((id.version, state.describe(), labels));
    }
    let models: Vec<(String, Vec<(u64, String, Vec<String>)>)> = by_model
        .into_iter()
        .map(|(name, mut versions)| {
            versions.sort_by_key(|(v, _, _)| *v);
            (name, versions)
        })
        .collect();
    HttpResponse::json(200, &codec::models_list_json(&models))
}

fn metadata(core: &ServerCore, spec: ModelSpec) -> HttpResponse {
    match core.handle(Request::GetModelMetadata { spec }) {
        Response::ModelMetadata { model, versions } => {
            HttpResponse::json(200, &codec::metadata_json(&model, &versions))
        }
        Response::Error { kind, message } => core_error(core, kind, &message),
        other => HttpResponse::error(500, &format!("unexpected response {other:?}")),
    }
}

fn delete_label(core: &ServerCore, spec: ModelSpec) -> HttpResponse {
    let label = spec.label.unwrap_or_default();
    match core.handle(Request::DeleteVersionLabel { model: spec.name, label }) {
        Response::Ack => HttpResponse::json(200, &Json::obj(vec![("ok", Json::Bool(true))])),
        Response::Error { kind, message } => core_error(core, kind, &message),
        other => HttpResponse::error(500, &format!("unexpected response {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(path: &str) -> Result<Route, (u16, String)> {
        parse_model_path(path)
    }

    #[test]
    fn model_paths_parse() {
        let r = parse("/v1/models/mnist").unwrap();
        assert_eq!(r.spec, ModelSpec::latest("mnist"));
        assert_eq!(r.verb, None);

        let r = parse("/v1/models/mnist:predict").unwrap();
        assert_eq!(r.verb, Some(Verb::Predict));
        assert_eq!(r.spec, ModelSpec::latest("mnist"));

        let r = parse("/v1/models/mnist/versions/3:classify").unwrap();
        assert_eq!(r.spec, ModelSpec::at_version("mnist", 3));
        assert_eq!(r.verb, Some(Verb::Classify));

        let r = parse("/v1/models/mnist/labels/canary:regress").unwrap();
        assert_eq!(r.spec, ModelSpec::with_label("mnist", "canary"));
        assert_eq!(r.verb, Some(Verb::Regress));

        // Percent-encoded model names decode per segment.
        let r = parse("/v1/models/my%20model").unwrap();
        assert_eq!(r.spec.name, "my model");
    }

    #[test]
    fn bad_paths_rejected_with_status() {
        assert_eq!(parse("/v2/models/m").unwrap_err().0, 404);
        assert_eq!(parse("/v1/models/").unwrap_err().0, 404);
        assert_eq!(parse("/v1/models/m/other/1").unwrap_err().0, 404);
        assert_eq!(parse("/v1/models/m/versions/x:predict").unwrap_err().0, 400);
        assert_eq!(parse("/v1/models/m:transmogrify").unwrap_err().0, 400);
        assert_eq!(parse("/v1/models/m/labels/").unwrap_err().0, 404);
        assert_eq!(parse("/v1/models/m%zz").unwrap_err().0, 400);
    }

    #[test]
    fn error_status_maps_from_kind() {
        // The kind decides, regardless of message text.
        assert_eq!(error_status(ErrorKind::NotFound, "whatever"), 404);
        assert_eq!(error_status(ErrorKind::InvalidArgument, "whatever"), 400);
        assert_eq!(error_status(ErrorKind::FailedPrecondition, "whatever"), 503);
        // Graceful-degradation kinds: shed/drain → 503 (retry),
        // expired deadline → 504 (do NOT retry — the budget is gone).
        assert_eq!(error_status(ErrorKind::Unavailable, "overloaded"), 503);
        assert_eq!(error_status(ErrorKind::DeadlineExceeded, "too late"), 504);
        // A reworded message no longer breaks the mapping.
        assert_eq!(error_status(ErrorKind::NotFound, "nothing here"), 404);
    }

    #[test]
    fn deadline_header_parses_and_rejects_garbage() {
        let mk = |value: Option<&str>| HttpRequest {
            method: "POST".into(),
            path: "/v1/models/m:predict".into(),
            query: String::new(),
            headers: value
                .map(|v| vec![("x-request-deadline-ms".to_string(), v.to_string())])
                .unwrap_or_default(),
            body: Vec::new(),
        };
        assert_eq!(deadline_of(&mk(None)).unwrap(), None);
        assert_eq!(deadline_of(&mk(Some("250"))).unwrap(), Some(250));
        assert_eq!(deadline_of(&mk(Some(" 9 "))).unwrap(), Some(9));
        let resp = deadline_of(&mk(Some("soon"))).unwrap_err();
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn kindless_errors_rescue_lookups_else_500() {
        // Unkinded errors: lookup-shaped messages keep their 404 via
        // the legacy substring table…
        for message in [
            "servable 'ghost' not found",
            "servable 'm' has no ready versions",
            "servable 'm' version 9 not ready",
            "model 'm' has no version labeled 'canary' (known labels: [])",
            "model 'm' has no version 9",
            "model 'm' has no versions",
        ] {
            assert_eq!(error_status(ErrorKind::Internal, message), 404, "{message}");
        }
        // …and anything else unclassified is a server fault. (The
        // request-caused rejections that used to land here — shape,
        // ladder, spec conflicts — now carry InvalidArgument from
        // their creation sites and answer 400 via the kind.)
        for message in ["device on fire", "batch run failed: execute: oom"] {
            assert_eq!(error_status(ErrorKind::Internal, message), 500, "{message}");
        }
    }
}
