//! REST routing: TF-Serving-shaped URLs dispatched through the same
//! [`ServerCore::handle`] the binary RPC server uses, so signatures,
//! version labels, batching and lifecycle behave identically on both
//! planes.
//!
//! ```text
//! POST   /v1/models/{name}[/versions/{v}|/labels/{l}]:predict
//! POST   /v1/models/{name}[/versions/{v}|/labels/{l}]:classify
//! POST   /v1/models/{name}[/versions/{v}|/labels/{l}]:regress
//! GET    /v1/models/{name}[/versions/{v}|/labels/{l}]     (metadata)
//! DELETE /v1/models/{name}/labels/{l}                     (drop label)
//! GET    /healthz
//! GET    /metrics
//! ```
//!
//! Errors use one envelope, `{"error": "..."}`. Status codes map
//! structurally from the core's typed [`ErrorKind`]: lookup failures
//! (unknown model/version/label) are 404, validation failures (shape,
//! signature, conflicting spec) are 400, retryable refusals (version
//! unloading mid-request, load shedding, drain) are 503 with a
//! `Retry-After` hint, and expired per-request deadlines are 504.
//! Errors without a kind are server faults (500), except lookup-shaped
//! messages, which the legacy substring table still rescues to 404.
//!
//! Data-plane POSTs honor an `X-Request-Deadline-Ms` header: the whole
//! request (queueing included) must finish within that many
//! milliseconds of arrival or it is dropped before execution.

use super::codec;
use super::expose;
use super::server::{BodySink, HttpHandler, HttpRequest, HttpResponse, SinkFactory};
use super::wire::{self, Codec};
use crate::base::error::ErrorKind;
use crate::inference::ModelSpec;
use crate::rpc::proto::{Request, Response};
use crate::server::builder::ServerCore;
use crate::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

/// Data-plane verb carried as a `:suffix` on the model path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Verb {
    Predict,
    Classify,
    Regress,
}

/// A parsed `/v1/models/...` URL.
#[derive(Debug, PartialEq)]
pub(crate) struct Route {
    pub spec: ModelSpec,
    pub verb: Option<Verb>,
}

/// Build the gateway's request handler over a shared [`ServerCore`].
pub fn gateway(core: Arc<ServerCore>) -> HttpHandler {
    Arc::new(move |req: &HttpRequest| {
        let t0 = Instant::now();
        let resp = route(&core, req);
        observe(&core, t0, &resp);
        resp
    })
}

/// The gateway-wide request metrics, shared by the buffered handler
/// and the streaming-sink completion path.
fn observe(core: &ServerCore, t0: Instant, resp: &HttpResponse) {
    core.registry.counter("http.requests").inc();
    if resp.status >= 400 {
        core.registry.counter("http.errors").inc();
    }
    core.registry
        .histogram("http.latency_ns")
        .record_duration(t0.elapsed());
}

/// Build the streaming-body factory paired with [`gateway`]: data-plane
/// POSTs whose codecs negotiate cleanly stream their body bytes into
/// the negotiated codec's incremental decoder as they come off the
/// socket (chunked or `Content-Length` alike) — predict tensor
/// elements land in pooled storage while the upload is in flight.
/// Every other request (including negotiation failures, which must
/// answer 415/406) buffers and goes through the plain handler.
pub fn sink_factory(core: Arc<ServerCore>) -> SinkFactory {
    Arc::new(move |req: &HttpRequest| {
        if req.method != "POST" {
            return None;
        }
        let route = parse_model_path(&req.path).ok()?;
        let verb = route.verb?;
        let (ingress, egress) = negotiate(req).ok()?;
        let decoder = match (verb, ingress.name()) {
            (Verb::Predict, "simd-json") => {
                StreamDecoder::JsonPredict(wire::simd::FastPredictParser::new())
            }
            (Verb::Predict, "binary") => {
                StreamDecoder::BinaryPredict(wire::binary::BinaryPredictStream::new())
            }
            // Scalar-pinned JSON and the examples verbs decode whole:
            // still streamed through the transport, buffered here.
            _ => StreamDecoder::Buffer(Vec::new()),
        };
        Some(Box::new(GatewaySink {
            core: Arc::clone(&core),
            spec: route.spec,
            verb,
            ingress,
            egress,
            decoder,
        }) as Box<dyn BodySink>)
    })
}

/// Per-request streaming state behind the [`BodySink`] seam.
enum StreamDecoder {
    /// SIMD JSON predict: hot bodies decode as bytes arrive; a bail
    /// retains the raw bytes for the scalar re-parse at finish.
    JsonPredict(wire::simd::FastPredictParser),
    /// Binary predict: framing decoded incrementally, floats written
    /// straight into pooled storage.
    BinaryPredict(wire::binary::BinaryPredictStream),
    /// Everything else: accumulate, decode whole at finish.
    Buffer(Vec<u8>),
}

struct GatewaySink {
    core: Arc<ServerCore>,
    spec: ModelSpec,
    verb: Verb,
    ingress: &'static dyn Codec,
    egress: &'static dyn Codec,
    decoder: StreamDecoder,
}

impl BodySink for GatewaySink {
    fn feed(&mut self, chunk: &[u8]) {
        match &mut self.decoder {
            StreamDecoder::JsonPredict(parser) => parser.feed(chunk),
            StreamDecoder::BinaryPredict(stream) => stream.feed(chunk),
            StreamDecoder::Buffer(buf) => buf.extend_from_slice(chunk),
        }
    }

    fn finish(self: Box<Self>, req: &HttpRequest) -> HttpResponse {
        let t0 = Instant::now();
        let this = *self;
        let core = Arc::clone(&this.core);
        let resp = this.respond(req);
        observe(&core, t0, &resp);
        resp
    }
}

impl GatewaySink {
    fn respond(self, req: &HttpRequest) -> HttpResponse {
        let deadline_ms = match deadline_of(req) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        let GatewaySink { core, spec, verb, ingress, egress, decoder } = self;
        match decoder {
            StreamDecoder::JsonPredict(parser) => {
                let parsed = match parser.finish() {
                    wire::simd::FastResult::Parsed(p) => Ok(p),
                    wire::simd::FastResult::Fallback(raw) => codec::parse_predict_body(&raw),
                };
                match parsed {
                    Ok(p) => run_predict(&core, p, spec, deadline_ms, egress),
                    Err(e) => HttpResponse::error(400, &e.to_string()),
                }
            }
            StreamDecoder::BinaryPredict(stream) => match stream.finish() {
                Ok(p) => run_predict(&core, p, spec, deadline_ms, egress),
                Err(e) => HttpResponse::error(400, &e.to_string()),
            },
            StreamDecoder::Buffer(body) => match verb {
                Verb::Predict => match ingress.decode_predict(&body) {
                    Ok(p) => run_predict(&core, p, spec, deadline_ms, egress),
                    Err(e) => HttpResponse::error(400, &e.to_string()),
                },
                Verb::Classify | Verb::Regress => match ingress.decode_examples(&body) {
                    Ok(p) => run_examples(&core, p, spec, verb, deadline_ms, egress),
                    Err(e) => HttpResponse::error(400, &e.to_string()),
                },
            },
        }
    }
}

fn route(core: &ServerCore, req: &HttpRequest) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => HttpResponse::text(200, "ok\n"),
        ("GET", "/metrics") => HttpResponse::text(200, &expose::metrics_text(core)),
        ("GET", "/v1/models") => models_list(core),
        _ if req.path.starts_with("/v1/models/") => models_route(core, req),
        (method, path) => {
            HttpResponse::error(404, &format!("no route for {method} {path}"))
        }
    }
}

fn models_route(core: &ServerCore, req: &HttpRequest) -> HttpResponse {
    let route = match parse_model_path(&req.path) {
        Ok(r) => r,
        Err((status, message)) => return HttpResponse::error(status, &message),
    };
    match (req.method.as_str(), route.verb) {
        ("POST", Some(verb)) => {
            let deadline_ms = match deadline_of(req) {
                Ok(d) => d,
                Err(resp) => return resp,
            };
            data_plane(core, req, route.spec, verb, deadline_ms)
        }
        ("GET", None) => metadata(core, route.spec),
        ("DELETE", None) if route.spec.label.is_some() => delete_label(core, route.spec),
        ("POST", None) => HttpResponse::error(
            400,
            "POST requires a :predict, :classify or :regress suffix",
        ),
        (method, _) => HttpResponse::error(
            405,
            &format!("method {method} not allowed for {}", req.path),
        ),
    }
}

/// Parse `/v1/models/{name}[/versions/{v}|/labels/{l}]` with an
/// optional `:verb` suffix. Errors carry the HTTP status to answer.
pub(crate) fn parse_model_path(path: &str) -> Result<Route, (u16, String)> {
    let rest = path
        .strip_prefix("/v1/models/")
        .ok_or_else(|| (404, format!("no route for {path}")))?;
    let (target, verb) = match rest.rsplit_once(':') {
        Some((t, v)) => {
            let verb = match v {
                "predict" => Verb::Predict,
                "classify" => Verb::Classify,
                "regress" => Verb::Regress,
                other => return Err((400, format!("unknown method ':{other}'"))),
            };
            (t, Some(verb))
        }
        None => (rest, None),
    };
    let segments: Option<Vec<String>> = target.split('/').map(percent_decode).collect();
    let segments = segments.ok_or_else(|| (400, format!("bad percent-encoding in {path}")))?;
    let spec = match segments.as_slice() {
        [name] if !name.is_empty() => ModelSpec::latest(name.clone()),
        [name, kind, version] if kind.as_str() == "versions" && !name.is_empty() => {
            let v: u64 = version
                .parse()
                .map_err(|_| (400, format!("bad version number {version:?}")))?;
            ModelSpec::at_version(name.clone(), v)
        }
        [name, kind, label]
            if kind.as_str() == "labels" && !name.is_empty() && !label.is_empty() =>
        {
            ModelSpec::with_label(name.clone(), label.clone())
        }
        _ => return Err((404, format!("no route for {path}"))),
    };
    Ok(Route { spec, verb })
}

fn percent_decode(s: &str) -> Option<String> {
    if !s.contains('%') {
        return Some(s.to_string());
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            let hex = bytes.get(i + 1..i + 3)?;
            let v = u8::from_str_radix(std::str::from_utf8(hex).ok()?, 16).ok()?;
            out.push(v);
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).ok()
}

/// HTTP status for a typed core error. The kind decides structurally:
/// `NotFound` → 404, `InvalidArgument` → 400, `FailedPrecondition`
/// (unload races, load shedding — retryable) → 503. `Internal` means
/// the error never got a kind: lookup-shaped messages are rescued to
/// 404 by the legacy substring table, and everything else is what it
/// says — a server fault, 500 (request-caused rejections all carry
/// `InvalidArgument` at their creation site now).
fn error_status(kind: ErrorKind, message: &str) -> u16 {
    match kind {
        ErrorKind::NotFound => 404,
        ErrorKind::InvalidArgument => 400,
        ErrorKind::FailedPrecondition => 503,
        ErrorKind::Unavailable => 503,
        ErrorKind::DeadlineExceeded => 504,
        ErrorKind::Internal => {
            const NOT_FOUND: [&str; 4] =
                ["not found", "no ready versions", "not ready", "no version"];
            if NOT_FOUND.iter().any(|n| message.contains(n)) {
                404
            } else {
                500
            }
        }
    }
}

fn core_error(core: &ServerCore, kind: ErrorKind, message: &str) -> HttpResponse {
    let status = error_status(kind, message);
    let resp = HttpResponse::error(status, message);
    if status == 503 {
        // Retryable refusal: tell well-behaved clients when to come
        // back instead of letting them hammer an overloaded server.
        resp.with_header("Retry-After", core.admission.retry_after_secs().to_string())
    } else {
        resp
    }
}

/// Per-request deadline from the `X-Request-Deadline-Ms` header.
fn deadline_of(req: &HttpRequest) -> Result<Option<u64>, HttpResponse> {
    match req.header("x-request-deadline-ms") {
        None => Ok(None),
        Some(v) => v.trim().parse::<u64>().map(Some).map_err(|_| {
            HttpResponse::error(400, &format!("bad X-Request-Deadline-Ms value {v:?}"))
        }),
    }
}

/// Wrap a core request in the deadline envelope when the header asked
/// for one (the core unwraps it into `RunOptions`).
fn with_deadline(req: Request, deadline_ms: Option<u64>) -> Request {
    match deadline_ms {
        Some(ms) => req.with_deadline_ms(ms),
        None => req,
    }
}

/// Pick the ingress codec from `Content-Type` and the egress codec
/// from `Accept`; failures are ready-to-send 415/406 responses.
fn negotiate(
    req: &HttpRequest,
) -> Result<(&'static dyn Codec, &'static dyn Codec), HttpResponse> {
    let ingress = wire::ingress_codec(req.header("content-type"))?;
    let egress = wire::egress_codec(req.header("accept"), ingress)?;
    Ok((ingress, egress))
}

/// A 200 whose body came out of a wire codec.
fn ok_response(enc: wire::Encoded) -> HttpResponse {
    HttpResponse {
        status: 200,
        content_type: enc.content_type,
        headers: Vec::new(),
        body: enc.body,
    }
}

fn data_plane(
    core: &ServerCore,
    req: &HttpRequest,
    spec: ModelSpec,
    verb: Verb,
    deadline_ms: Option<u64>,
) -> HttpResponse {
    let (ingress, egress) = match negotiate(req) {
        Ok(pair) => pair,
        Err(resp) => return resp,
    };
    match verb {
        Verb::Predict => match ingress.decode_predict(&req.body) {
            Ok(parsed) => run_predict(core, parsed, spec, deadline_ms, egress),
            Err(e) => HttpResponse::error(400, &e.to_string()),
        },
        Verb::Classify | Verb::Regress => match ingress.decode_examples(&req.body) {
            Ok(parsed) => run_examples(core, parsed, spec, verb, deadline_ms, egress),
            Err(e) => HttpResponse::error(400, &e.to_string()),
        },
    }
}

/// Execute a decoded predict against the core and encode the reply
/// with the negotiated egress codec.
fn run_predict(
    core: &ServerCore,
    parsed: codec::PredictBody,
    spec: ModelSpec,
    deadline_ms: Option<u64>,
    egress: &'static dyn Codec,
) -> HttpResponse {
    let row_format = parsed.row_format;
    let resp = core.handle(with_deadline(
        Request::Predict {
            spec,
            signature: parsed.signature,
            inputs: parsed.inputs,
        },
        deadline_ms,
    ));
    if let Response::Error { kind, message } = &resp {
        return core_error(core, *kind, message);
    }
    if !matches!(resp, Response::Predict { .. }) {
        return HttpResponse::error(500, &format!("unexpected response {resp:?}"));
    }
    let result = match egress.encode_predict(&resp, row_format) {
        Ok(enc) => ok_response(enc),
        Err(e) => HttpResponse::error(500, &e.to_string()),
    };
    // The reply is serialized; sole-owner output storage goes back to
    // the pools, same as the RPC reply path.
    resp.recycle_buffers();
    result
}

/// Execute a decoded classify/regress against the core.
fn run_examples(
    core: &ServerCore,
    parsed: codec::ExamplesBody,
    spec: ModelSpec,
    verb: Verb,
    deadline_ms: Option<u64>,
    egress: &'static dyn Codec,
) -> HttpResponse {
    match verb {
        Verb::Classify => match core.handle(with_deadline(
            Request::Classify {
                spec,
                signature: parsed.signature,
                examples: parsed.examples,
            },
            deadline_ms,
        )) {
            Response::Classify { model_version, classes, log_probs } => {
                ok_response(egress.encode_classify(model_version, &classes, &log_probs))
            }
            Response::Error { kind, message } => core_error(core, kind, &message),
            other => HttpResponse::error(500, &format!("unexpected response {other:?}")),
        },
        Verb::Regress => match core.handle(with_deadline(
            Request::Regress {
                spec,
                signature: parsed.signature,
                examples: parsed.examples,
            },
            deadline_ms,
        )) {
            Response::Regress { model_version, values } => {
                ok_response(egress.encode_regress(model_version, &values))
            }
            Response::Error { kind, message } => core_error(core, kind, &message),
            other => HttpResponse::error(500, &format!("unexpected response {other:?}")),
        },
        Verb::Predict => unreachable!("predict bodies never decode as examples"),
    }
}

/// `GET /v1/models`: fleet inventory — every model the server holds,
/// with per-version state and labels, from the lifecycle monitor.
fn models_list(core: &ServerCore) -> HttpResponse {
    let mut by_model: std::collections::BTreeMap<String, Vec<(u64, String, Vec<String>)>> =
        Default::default();
    for (id, state) in core.avm().monitor().snapshot() {
        let labels = core.labels.labels_of_version(&id.name, id.version);
        by_model
            .entry(id.name)
            .or_default()
            .push((id.version, state.describe(), labels));
    }
    let models: Vec<(String, Vec<(u64, String, Vec<String>)>, Option<String>)> = by_model
        .into_iter()
        .map(|(name, mut versions)| {
            versions.sort_by_key(|(v, _, _)| *v);
            // Fleet rollout status (canary phase / rollback reason),
            // when the control plane has pushed one to this replica.
            let rollout = core.rollout_status_of(&name);
            (name, versions, rollout)
        })
        .collect();
    HttpResponse::json(200, &codec::models_list_json(&models))
}

fn metadata(core: &ServerCore, spec: ModelSpec) -> HttpResponse {
    match core.handle(Request::GetModelMetadata { spec }) {
        Response::ModelMetadata { model, versions } => {
            HttpResponse::json(200, &codec::metadata_json(&model, &versions))
        }
        Response::Error { kind, message } => core_error(core, kind, &message),
        other => HttpResponse::error(500, &format!("unexpected response {other:?}")),
    }
}

fn delete_label(core: &ServerCore, spec: ModelSpec) -> HttpResponse {
    let label = spec.label.unwrap_or_default();
    match core.handle(Request::DeleteVersionLabel { model: spec.name, label }) {
        Response::Ack => HttpResponse::json(200, &Json::obj(vec![("ok", Json::Bool(true))])),
        Response::Error { kind, message } => core_error(core, kind, &message),
        other => HttpResponse::error(500, &format!("unexpected response {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(path: &str) -> Result<Route, (u16, String)> {
        parse_model_path(path)
    }

    #[test]
    fn model_paths_parse() {
        let r = parse("/v1/models/mnist").unwrap();
        assert_eq!(r.spec, ModelSpec::latest("mnist"));
        assert_eq!(r.verb, None);

        let r = parse("/v1/models/mnist:predict").unwrap();
        assert_eq!(r.verb, Some(Verb::Predict));
        assert_eq!(r.spec, ModelSpec::latest("mnist"));

        let r = parse("/v1/models/mnist/versions/3:classify").unwrap();
        assert_eq!(r.spec, ModelSpec::at_version("mnist", 3));
        assert_eq!(r.verb, Some(Verb::Classify));

        let r = parse("/v1/models/mnist/labels/canary:regress").unwrap();
        assert_eq!(r.spec, ModelSpec::with_label("mnist", "canary"));
        assert_eq!(r.verb, Some(Verb::Regress));

        // Percent-encoded model names decode per segment.
        let r = parse("/v1/models/my%20model").unwrap();
        assert_eq!(r.spec.name, "my model");
    }

    #[test]
    fn bad_paths_rejected_with_status() {
        assert_eq!(parse("/v2/models/m").unwrap_err().0, 404);
        assert_eq!(parse("/v1/models/").unwrap_err().0, 404);
        assert_eq!(parse("/v1/models/m/other/1").unwrap_err().0, 404);
        assert_eq!(parse("/v1/models/m/versions/x:predict").unwrap_err().0, 400);
        assert_eq!(parse("/v1/models/m:transmogrify").unwrap_err().0, 400);
        assert_eq!(parse("/v1/models/m/labels/").unwrap_err().0, 404);
        assert_eq!(parse("/v1/models/m%zz").unwrap_err().0, 400);
    }

    #[test]
    fn error_status_maps_from_kind() {
        // The kind decides, regardless of message text.
        assert_eq!(error_status(ErrorKind::NotFound, "whatever"), 404);
        assert_eq!(error_status(ErrorKind::InvalidArgument, "whatever"), 400);
        assert_eq!(error_status(ErrorKind::FailedPrecondition, "whatever"), 503);
        // Graceful-degradation kinds: shed/drain → 503 (retry),
        // expired deadline → 504 (do NOT retry — the budget is gone).
        assert_eq!(error_status(ErrorKind::Unavailable, "overloaded"), 503);
        assert_eq!(error_status(ErrorKind::DeadlineExceeded, "too late"), 504);
        // A reworded message no longer breaks the mapping.
        assert_eq!(error_status(ErrorKind::NotFound, "nothing here"), 404);
    }

    #[test]
    fn deadline_header_parses_and_rejects_garbage() {
        let mk = |value: Option<&str>| HttpRequest {
            method: "POST".into(),
            path: "/v1/models/m:predict".into(),
            query: String::new(),
            headers: value
                .map(|v| vec![("x-request-deadline-ms".to_string(), v.to_string())])
                .unwrap_or_default(),
            body: Vec::new(),
        };
        assert_eq!(deadline_of(&mk(None)).unwrap(), None);
        assert_eq!(deadline_of(&mk(Some("250"))).unwrap(), Some(250));
        assert_eq!(deadline_of(&mk(Some(" 9 "))).unwrap(), Some(9));
        let resp = deadline_of(&mk(Some("soon"))).unwrap_err();
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn kindless_errors_rescue_lookups_else_500() {
        // Unkinded errors: lookup-shaped messages keep their 404 via
        // the legacy substring table…
        for message in [
            "servable 'ghost' not found",
            "servable 'm' has no ready versions",
            "servable 'm' version 9 not ready",
            "model 'm' has no version labeled 'canary' (known labels: [])",
            "model 'm' has no version 9",
            "model 'm' has no versions",
        ] {
            assert_eq!(error_status(ErrorKind::Internal, message), 404, "{message}");
        }
        // …and anything else unclassified is a server fault. (The
        // request-caused rejections that used to land here — shape,
        // ladder, spec conflicts — now carry InvalidArgument from
        // their creation sites and answer 400 via the kind.)
        for message in ["device on fire", "batch run failed: execute: oom"] {
            assert_eq!(error_status(ErrorKind::Internal, message), 500, "{message}");
        }
    }
}
