//! Minimal HTTP/1.1 server — the REST gateway's front door.
//!
//! Dependency-free by necessity (the offline crate set has no HTTP
//! stack). By default the listener is a thin binding onto the shared
//! epoll reactor ([`crate::net`]): connections are nonblocking state
//! machines ([`crate::net::conn::HttpProto`] reuses this module's
//! parser) and handlers run on the bounded worker pool. The original
//! thread-per-connection accept loop survives behind
//! `net.mode = "threaded"` (and as the automatic fallback where epoll
//! is unavailable). Implements the slice of HTTP/1.1 a serving data
//! plane needs:
//!
//! * **keep-alive** (default on 1.1, honoring `Connection:` headers),
//!   so load generators and proxies reuse connections;
//! * request bodies via **`Content-Length`** or **chunked**
//!   transfer-encoding (what `curl -T`/streaming clients send);
//! * `Expect: 100-continue` handshake;
//! * hard **size limits** on the request line, header count/length and
//!   body (the body cap matches the RPC frame cap), so an
//!   internet-facing listener cannot be ballooned;
//! * single-`write` responses: status line + headers + body are
//!   assembled in a per-connection scratch buffer and leave in one
//!   syscall, mirroring the RPC server's framed reply path.
//!
//! Routing and JSON live elsewhere ([`super::router`],
//! [`super::codec`]); the handler here is a pure
//! `HttpRequest → HttpResponse` function.

use crate::net::conn::HttpProto;
use crate::net::reactor::{ListenerId, Reactor};
use crate::net::track::ConnTracker;
use crate::net::{conn::ProtocolFactory, NetConfig, NetMetrics};
use crate::util::json::Json;
use crate::util::metrics::Registry;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Maximum request-line length (method + target + version).
pub const MAX_REQUEST_LINE: usize = 8 << 10;
/// Maximum length of a single header line.
pub const MAX_HEADER_LINE: usize = 8 << 10;
/// Maximum number of headers per request.
pub const MAX_HEADERS: usize = 100;
/// Maximum request body, matching the RPC layer's frame cap.
pub const MAX_BODY: usize = crate::rpc::frame::MAX_FRAME;
/// Default idle timeout (see `NetConfig::idle_timeout`): on the
/// reactor path the sweep closes idle connections; on the threaded
/// path it is the socket read timeout that bounds how long an idle
/// keep-alive connection can pin its handler thread.
pub const READ_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(60);

/// A parsed request.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpRequest {
    /// Upper-case method as sent ("GET", "POST", "DELETE", …).
    pub method: String,
    /// Percent-encoded path with the query string split off.
    pub path: String,
    /// Raw query string (without the '?'); empty when absent.
    pub query: String,
    /// Headers in arrival order, names lower-cased, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header with this (lower-case) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// A response the handler hands back; the server adds framing headers.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: &'static str,
    /// Extra response headers (e.g. `Retry-After` on a 503); the
    /// framing headers are added by the server and must not appear
    /// here.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn json(status: u16, body: &Json) -> HttpResponse {
        HttpResponse {
            status,
            content_type: "application/json",
            headers: Vec::new(),
            body: body.to_string().into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> HttpResponse {
        HttpResponse {
            status,
            content_type: "text/plain; charset=utf-8",
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    /// The gateway's uniform error envelope: `{"error": "..."}`.
    pub fn error(status: u16, message: &str) -> HttpResponse {
        HttpResponse::json(status, &Json::obj(vec![("error", Json::str(message))]))
    }

    /// Builder-style extra header.
    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> HttpResponse {
        self.headers.push((name.to_string(), value.into()));
        self
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        100 => "Continue",
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        406 => "Not Acceptable",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        415 => "Unsupported Media Type",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

/// Parse failure carrying the status the peer should see.
#[derive(Debug)]
pub(crate) struct HttpError {
    pub(crate) status: u16,
    pub(crate) message: String,
}

fn herr(status: u16, message: impl Into<String>) -> HttpError {
    HttpError { status, message: message.into() }
}

/// Handler: pure function from request to response; runs on connection
/// threads, so shared state must be Sync.
pub type HttpHandler = Arc<dyn Fn(&HttpRequest) -> HttpResponse + Send + Sync>;

/// An incremental body consumer: request bytes stream into `feed` as
/// they arrive off the socket — chunked uploads decode chunk by chunk,
/// `Content-Length` bodies slice by slice — instead of being buffered
/// whole and handed to the handler at the end. The wire codecs hang
/// their streaming decoders off this seam, so tensor elements land in
/// pooled storage while the upload is still in flight.
///
/// `feed` is infallible by design: a decoder that goes sour latches
/// the error and reports it from `finish`, which keeps the transport
/// loop free of per-chunk error plumbing (and mirrors the codecs'
/// complete-or-bail contract).
pub trait BodySink: Send {
    fn feed(&mut self, chunk: &[u8]);
    /// All body bytes are in: produce the response. `req` is the
    /// request head (its `body` is empty — the bytes went here).
    fn finish(self: Box<Self>, req: &HttpRequest) -> HttpResponse;
}

/// Decides, per request head, whether the body should stream into a
/// [`BodySink`] (`Some`) or be buffered whole for the plain
/// [`HttpHandler`] (`None`). Runs on the transport thread before any
/// body byte is read.
pub type SinkFactory = Arc<dyn Fn(&HttpRequest) -> Option<Box<dyn BodySink>> + Send + Sync>;

/// The canned over-`max_connections` reply: an immediate 503 with
/// `Retry-After`, mirroring admission-control shedding.
pub(crate) fn http_reject_bytes() -> Vec<u8> {
    let resp = HttpResponse::error(503, "connection limit reached, retry against another replica")
        .with_header("Retry-After", "1");
    let mut buf = Vec::new();
    render_response(&mut buf, &resp, false);
    buf
}

enum Mode {
    /// Thin binding onto an epoll reactor; `owned` reactors (built by
    /// the standalone constructor) are stopped with the server.
    Reactor {
        stack: Arc<Reactor>,
        listener: ListenerId,
        owned: bool,
    },
    /// Legacy thread-per-connection accept loop.
    Threaded {
        shutdown: Arc<AtomicBool>,
        accept_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
        conns: Arc<ConnTracker>,
    },
}

pub struct HttpServer {
    addr: SocketAddr,
    requests_served: Arc<AtomicU64>,
    mode: Mode,
    stopped: AtomicBool,
}

impl HttpServer {
    /// Bind and serve `handler` on `addr` (port 0 = ephemeral; read the
    /// bound address back from [`HttpServer::addr`]). Runs on a private
    /// single-thread reactor (default [`NetConfig`]); falls back to the
    /// threaded accept loop where epoll is unavailable.
    pub fn start(addr: &str, handler: HttpHandler) -> anyhow::Result<Arc<Self>> {
        let cfg = NetConfig::default();
        match Reactor::start(&cfg, NetMetrics::register(&Registry::new())) {
            Ok(stack) => Self::start_on(addr, handler, None, &stack, true),
            Err(e) => {
                crate::log_warn!("epoll reactor unavailable ({e}); using threaded listener");
                Self::start_threaded(addr, handler, &cfg)
            }
        }
    }

    /// Bind onto a shared reactor (the assembled server's I/O plane).
    /// `stop()` closes this listener only; the reactor outlives it.
    pub fn start_shared(
        addr: &str,
        handler: HttpHandler,
        stack: &Arc<Reactor>,
    ) -> anyhow::Result<Arc<Self>> {
        Self::start_on(addr, handler, None, stack, false)
    }

    /// [`start_shared`](Self::start_shared) with a [`SinkFactory`]:
    /// request heads the factory claims stream their bodies into the
    /// sink as bytes arrive; everything else buffers and goes to
    /// `handler` as before.
    pub fn start_shared_with(
        addr: &str,
        handler: HttpHandler,
        sinks: SinkFactory,
        stack: &Arc<Reactor>,
    ) -> anyhow::Result<Arc<Self>> {
        Self::start_on(addr, handler, Some(sinks), stack, false)
    }

    fn start_on(
        addr: &str,
        handler: HttpHandler,
        sinks: Option<SinkFactory>,
        stack: &Arc<Reactor>,
        owned: bool,
    ) -> anyhow::Result<Arc<Self>> {
        let listener = TcpListener::bind(addr)?;
        let requests_served = Arc::new(AtomicU64::new(0));
        let (make_handler, make_served) = (Arc::clone(&handler), Arc::clone(&requests_served));
        let factory = ProtocolFactory {
            label: "http",
            make: Box::new(move || {
                Box::new(HttpProto::new_with(
                    Arc::clone(&make_handler),
                    Arc::clone(&make_served),
                    sinks.clone(),
                ))
            }),
            reject: http_reject_bytes(),
        };
        let (listener, local) = stack.add_listener(listener, factory)?;
        crate::log_info!("http server listening on {local} (reactor)");
        Ok(Arc::new(HttpServer {
            addr: local,
            requests_served,
            mode: Mode::Reactor { stack: Arc::clone(stack), listener, owned },
            stopped: AtomicBool::new(false),
        }))
    }

    /// Legacy thread-per-connection listener (`net.mode = "threaded"`
    /// and the non-epoll fallback). `cfg` supplies the idle/read
    /// timeout and the `max_connections` gate.
    pub fn start_threaded(
        addr: &str,
        handler: HttpHandler,
        cfg: &NetConfig,
    ) -> anyhow::Result<Arc<Self>> {
        Self::start_threaded_with(addr, handler, None, cfg)
    }

    /// [`start_threaded`](Self::start_threaded) with an optional
    /// [`SinkFactory`] for streaming body decode.
    pub fn start_threaded_with(
        addr: &str,
        handler: HttpHandler,
        sinks: Option<SinkFactory>,
        cfg: &NetConfig,
    ) -> anyhow::Result<Arc<Self>> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let requests_served = Arc::new(AtomicU64::new(0));
        let conns = Arc::new(ConnTracker::new());

        let accept_shutdown = Arc::clone(&shutdown);
        let accept_counter = Arc::clone(&requests_served);
        let accept_conns = Arc::clone(&conns);
        let idle_timeout = cfg.idle_timeout;
        let max_connections = cfg.max_connections;
        let accept_thread = std::thread::Builder::new()
            .name(format!("http-accept-{}", local.port()))
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    match stream {
                        Ok(mut stream) => {
                            if max_connections > 0 && accept_conns.len() >= max_connections {
                                let _ = stream.write_all(&http_reject_bytes());
                                continue;
                            }
                            let handler = Arc::clone(&handler);
                            let counter = Arc::clone(&accept_counter);
                            let sd = Arc::clone(&accept_shutdown);
                            let sinks = sinks.clone();
                            // Track before spawn so stop() can shut the
                            // socket down and join the thread instead of
                            // stranding it (detached-spawn bug).
                            let id = accept_conns.register(&stream);
                            let tracker = Arc::clone(&accept_conns);
                            let spawned = std::thread::Builder::new()
                                .name("http-conn".to_string())
                                .spawn(move || {
                                    Self::serve_connection(stream, handler, sinks, counter, sd, idle_timeout);
                                    if let Some(id) = id {
                                        tracker.deregister(id);
                                    }
                                });
                            if let (Some(id), Ok(handle)) = (id, spawned) {
                                accept_conns.attach(id, handle);
                            }
                        }
                        Err(e) => {
                            crate::log_warn!("http accept error: {e}");
                        }
                    }
                }
            })?;

        crate::log_info!("http server listening on {local} (threaded)");
        Ok(Arc::new(HttpServer {
            addr: local,
            requests_served,
            mode: Mode::Threaded {
                shutdown,
                accept_thread: Mutex::new(Some(accept_thread)),
                conns,
            },
            stopped: AtomicBool::new(false),
        }))
    }

    fn serve_connection(
        stream: TcpStream,
        handler: HttpHandler,
        sinks: Option<SinkFactory>,
        counter: Arc<AtomicU64>,
        shutdown: Arc<AtomicBool>,
        idle_timeout: std::time::Duration,
    ) {
        let _ = stream.set_nodelay(true);
        // Idle connections wake from `read` every idle_timeout: they
        // either observe shutdown or are dropped, so `stop()` never
        // strands a thread blocked on a silent keep-alive peer.
        let _ = stream.set_read_timeout(Some(idle_timeout));
        let mut reader = BufReader::new(stream);
        // Per-connection scratch for the assembled response: one
        // allocation reused across every request on this connection.
        let mut write_buf: Vec<u8> = Vec::new();
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            let mut req = match read_head(&mut reader) {
                Ok(Some(req)) => req,
                Ok(None) => return, // clean close at a request boundary
                Err(e) if e.status == 408 => return, // idle timeout: just close
                Err(e) => {
                    let resp = HttpResponse::error(e.status, &e.message);
                    let _ = write_response(&mut reader, &mut write_buf, &resp, false);
                    return;
                }
            };
            // A client waiting on 100-continue will not send the body
            // until told to. Don't invite an upload the framing checks
            // are about to reject (RFC 9110 §10.1.1): only confirm
            // when the declared length fits and the framing is sane;
            // read_body still makes the authoritative decision.
            let framing_plausible = req.header("transfer-encoding").is_none()
                || req.header("content-length").is_none();
            let length_plausible = req
                .header("content-length")
                .map_or(true, |v| matches!(v.parse::<usize>(), Ok(n) if n <= MAX_BODY));
            if req
                .header("expect")
                .map(|v| v.eq_ignore_ascii_case("100-continue"))
                .unwrap_or(false)
                && framing_plausible
                && length_plausible
            {
                if reader
                    .get_mut()
                    .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
                    .is_err()
                {
                    return;
                }
            }
            // Streaming path: if a sink claims this head, body bytes
            // feed it as they come off the socket — no whole-body
            // buffer — and the sink produces the response.
            if let Some(mut sink) = sinks.as_ref().and_then(|f| f(&req)) {
                if let Err(e) = stream_body(&mut reader, &req, sink.as_mut()) {
                    let resp = HttpResponse::error(e.status, &e.message);
                    let _ = write_response(&mut reader, &mut write_buf, &resp, false);
                    return;
                }
                let keep_alive = wants_keep_alive(&req);
                let resp = sink.finish(&req);
                counter.fetch_add(1, Ordering::Relaxed);
                if let Err(e) = write_response(&mut reader, &mut write_buf, &resp, keep_alive) {
                    crate::log_debug!("http write error: {e}");
                    return;
                }
                if !keep_alive {
                    return;
                }
                continue;
            }
            req.body = match read_body(&mut reader, &req) {
                Ok(body) => body,
                Err(e) => {
                    let resp = HttpResponse::error(e.status, &e.message);
                    let _ = write_response(&mut reader, &mut write_buf, &resp, false);
                    return;
                }
            };
            let keep_alive = wants_keep_alive(&req);
            let resp = handler(&req);
            counter.fetch_add(1, Ordering::Relaxed);
            if let Err(e) = write_response(&mut reader, &mut write_buf, &resp, keep_alive) {
                crate::log_debug!("http write error: {e}");
                return;
            }
            if !keep_alive {
                return;
            }
        }
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn requests_served(&self) -> u64 {
        self.requests_served.load(Ordering::Relaxed)
    }

    /// Stop accepting and release every connection. On the reactor
    /// path the listener closes and its connections are closed (idle
    /// ones now, in-flight ones after their reply flushes); a
    /// standalone server also stops its private reactor, which joins
    /// all threads. On the threaded path live connection sockets are
    /// shut down and their threads joined.
    pub fn stop(&self) {
        if self.stopped.swap(true, Ordering::SeqCst) {
            return;
        }
        match &self.mode {
            Mode::Reactor { stack, listener, owned } => {
                stack.close_listener(*listener);
                if *owned {
                    stack.stop();
                }
            }
            Mode::Threaded { shutdown, accept_thread, conns } => {
                shutdown.store(true, Ordering::SeqCst);
                // Poke the accept loop awake.
                let _ = TcpStream::connect(self.addr);
                if let Some(t) = accept_thread.lock().unwrap().take() {
                    let _ = t.join();
                }
                conns.stop_all();
            }
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// ----------------------------------------------------------- parsing

/// Read one line (up to `cap` bytes before the newline) from `r`,
/// stripping the trailing CRLF. `Ok(None)` = EOF before any byte.
fn read_line_limited<R: BufRead>(r: &mut R, cap: usize) -> Result<Option<String>, HttpError> {
    let mut raw = Vec::new();
    let got = r
        .by_ref()
        .take((cap + 2) as u64) // room for the CRLF itself
        .read_until(b'\n', &mut raw)
        .map_err(|e| {
            use std::io::ErrorKind;
            if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
                herr(408, "read timeout")
            } else {
                herr(400, format!("read error: {e}"))
            }
        })?;
    if got == 0 {
        return Ok(None);
    }
    if raw.last() != Some(&b'\n') {
        if raw.len() >= cap {
            return Err(herr(431, format!("line exceeds {cap} bytes")));
        }
        return Err(herr(400, "truncated request"));
    }
    raw.pop();
    if raw.last() == Some(&b'\r') {
        raw.pop();
    }
    if raw.len() > cap {
        return Err(herr(431, format!("line exceeds {cap} bytes")));
    }
    String::from_utf8(raw).map(Some).map_err(|_| herr(400, "non-utf8 request bytes"))
}

/// Read and parse the request line + headers; the body stays unread
/// (`req.body` comes back empty). `Ok(None)` = clean EOF before a
/// request started (keep-alive close). Shared with the reactor's
/// [`crate::net::conn::HttpProto`], which replays accumulated bytes
/// through a `Cursor`.
pub(crate) fn read_head<R: BufRead>(r: &mut R) -> Result<Option<HttpRequest>, HttpError> {
    // Tolerate stray CRLF between pipelined requests (RFC 9112 §2.2).
    let mut line = loop {
        match read_line_limited(r, MAX_REQUEST_LINE)? {
            None => return Ok(None),
            Some(l) if l.is_empty() => continue,
            Some(l) => break l,
        }
    };
    let mut parts = line.split_whitespace();
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => {
                (m.to_string(), t.to_string(), v.to_string())
            }
            _ => return Err(herr(400, format!("malformed request line {line:?}"))),
        };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(herr(400, format!("unsupported protocol version {version:?}")));
    }
    let (path, query) = match target.find('?') {
        Some(i) => (target[..i].to_string(), target[i + 1..].to_string()),
        None => (target, String::new()),
    };
    let mut headers = Vec::new();
    loop {
        line = match read_line_limited(r, MAX_HEADER_LINE)? {
            None => return Err(herr(400, "connection closed mid-headers")),
            Some(l) => l,
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(herr(431, format!("more than {MAX_HEADERS} headers")));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| herr(400, format!("malformed header {line:?}")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    // The HTTP version rides along as a pseudo-header so the keep-alive
    // decision (and tests) can see it without widening the struct.
    headers.push((":version".to_string(), version));
    Ok(Some(HttpRequest { method, path, query, headers, body: Vec::new() }))
}

/// How a request's body is delimited, per its framing headers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum BodyFraming {
    Empty,
    Length(usize),
    Chunked,
}

/// Decide the body framing from the head alone. Ambiguous framing is
/// rejected, never resolved (RFC 9112 §6): a proxy and this server
/// disagreeing on where a request ends is the request-smuggling
/// precondition. Over-`MAX_BODY` declared lengths are rejected here,
/// before any body byte is read.
pub(crate) fn body_framing(req: &HttpRequest) -> Result<BodyFraming, HttpError> {
    let lengths: Vec<&str> = req
        .headers
        .iter()
        .filter(|(n, _)| n == "content-length")
        .map(|(_, v)| v.as_str())
        .collect();
    if lengths.len() > 1 && lengths.iter().any(|&v| v != lengths[0]) {
        return Err(herr(400, "conflicting content-length headers"));
    }
    if let Some(te) = req.header("transfer-encoding") {
        if !lengths.is_empty() {
            return Err(herr(400, "both transfer-encoding and content-length present"));
        }
        if !te.eq_ignore_ascii_case("chunked") {
            return Err(herr(501, format!("unsupported transfer-encoding {te:?}")));
        }
        return Ok(BodyFraming::Chunked);
    }
    let len = match lengths.first() {
        None => return Ok(BodyFraming::Empty),
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| herr(400, format!("bad content-length {v:?}")))?,
    };
    if len > MAX_BODY {
        return Err(herr(413, format!("body of {len} bytes exceeds {MAX_BODY}")));
    }
    Ok(BodyFraming::Length(len))
}

/// Read the request body according to its framing headers.
fn read_body<R: BufRead>(r: &mut R, req: &HttpRequest) -> Result<Vec<u8>, HttpError> {
    let len = match body_framing(req)? {
        BodyFraming::Empty => return Ok(Vec::new()),
        BodyFraming::Chunked => return read_chunked(r),
        BodyFraming::Length(len) => len,
    };
    // Grow as bytes actually arrive: an attacker claiming a 64 MiB
    // Content-Length and then stalling must not pin 64 MiB per
    // connection up front.
    let mut body = Vec::with_capacity(len.min(64 << 10));
    let got = r
        .by_ref()
        .take(len as u64)
        .read_to_end(&mut body)
        .map_err(|e| herr(400, format!("read error: {e}")))?;
    if got < len {
        return Err(herr(400, "truncated body"));
    }
    Ok(body)
}

/// Read the request body according to its framing headers, feeding
/// each slice into `sink` as it arrives instead of buffering. Framing
/// rules, limits and error statuses match [`read_body`] exactly.
fn stream_body<R: BufRead>(
    r: &mut R,
    req: &HttpRequest,
    sink: &mut dyn BodySink,
) -> Result<(), HttpError> {
    let len = match body_framing(req)? {
        BodyFraming::Empty => return Ok(()),
        BodyFraming::Chunked => return stream_chunked(r, sink),
        BodyFraming::Length(len) => len,
    };
    let mut scratch = [0u8; 16 << 10];
    let mut remaining = len;
    while remaining > 0 {
        let want = remaining.min(scratch.len());
        let got = r
            .read(&mut scratch[..want])
            .map_err(|e| herr(400, format!("read error: {e}")))?;
        if got == 0 {
            return Err(herr(400, "truncated body"));
        }
        sink.feed(&scratch[..got]);
        remaining -= got;
    }
    Ok(())
}

/// Chunked counterpart of [`stream_body`]: decoded chunk data feeds
/// the sink; the cumulative cap still applies.
fn stream_chunked<R: BufRead>(r: &mut R, sink: &mut dyn BodySink) -> Result<(), HttpError> {
    let mut scratch = [0u8; 16 << 10];
    let mut total = 0usize;
    loop {
        let line = read_line_limited(r, 1024)?
            .ok_or_else(|| herr(400, "connection closed mid-chunk"))?;
        let size_str = line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_str, 16)
            .map_err(|_| herr(400, format!("bad chunk size {size_str:?}")))?;
        if total.saturating_add(size) > MAX_BODY {
            return Err(herr(413, format!("chunked body exceeds {MAX_BODY} bytes")));
        }
        if size == 0 {
            loop {
                match read_line_limited(r, MAX_HEADER_LINE)? {
                    None => return Err(herr(400, "connection closed mid-trailers")),
                    Some(l) if l.is_empty() => return Ok(()),
                    Some(_) => continue,
                }
            }
        }
        let mut remaining = size;
        while remaining > 0 {
            let want = remaining.min(scratch.len());
            let got = r
                .read(&mut scratch[..want])
                .map_err(|e| herr(400, format!("read error: {e}")))?;
            if got == 0 {
                return Err(herr(400, "truncated chunk"));
            }
            sink.feed(&scratch[..got]);
            remaining -= got;
        }
        total += size;
        let mut crlf = [0u8; 2];
        r.read_exact(&mut crlf).map_err(|_| herr(400, "truncated chunk"))?;
        if &crlf != b"\r\n" {
            return Err(herr(400, "chunk missing CRLF terminator"));
        }
    }
}

fn read_chunked<R: BufRead>(r: &mut R) -> Result<Vec<u8>, HttpError> {
    let mut body = Vec::new();
    loop {
        let line = read_line_limited(r, 1024)?
            .ok_or_else(|| herr(400, "connection closed mid-chunk"))?;
        // Chunk extensions after ';' are allowed and ignored.
        let size_str = line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_str, 16)
            .map_err(|_| herr(400, format!("bad chunk size {size_str:?}")))?;
        if body.len().saturating_add(size) > MAX_BODY {
            return Err(herr(413, format!("chunked body exceeds {MAX_BODY} bytes")));
        }
        if size == 0 {
            // Trailers (ignored) until the blank line.
            loop {
                match read_line_limited(r, MAX_HEADER_LINE)? {
                    None => return Err(herr(400, "connection closed mid-trailers")),
                    Some(l) if l.is_empty() => return Ok(body),
                    Some(_) => continue,
                }
            }
        }
        // Incremental append for the same reason as read_body: the
        // claimed chunk size must not drive a large upfront alloc.
        let start = body.len();
        let got = r
            .by_ref()
            .take(size as u64)
            .read_to_end(&mut body)
            .map_err(|e| herr(400, format!("read error: {e}")))?;
        if got < size || body.len() != start + size {
            return Err(herr(400, "truncated chunk"));
        }
        let mut crlf = [0u8; 2];
        r.read_exact(&mut crlf).map_err(|_| herr(400, "truncated chunk"))?;
        if &crlf != b"\r\n" {
            return Err(herr(400, "chunk missing CRLF terminator"));
        }
    }
}

pub(crate) fn wants_keep_alive(req: &HttpRequest) -> bool {
    let default = req.header(":version") != Some("HTTP/1.0");
    match req.header("connection") {
        Some(v) if v.eq_ignore_ascii_case("close") => false,
        Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
        _ => default,
    }
}

/// Render a full response (status line + framing headers + body) into
/// `buf`, which is cleared first. Shared by the threaded write path
/// and the reactor's worker-side encoding.
pub(crate) fn render_response(buf: &mut Vec<u8>, resp: &HttpResponse, keep_alive: bool) {
    buf.clear();
    // write! straight into the scratch Vec: no intermediate header
    // String on the per-request path (Vec<u8>'s io::Write is
    // infallible).
    let _ = write!(
        buf,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in &resp.headers {
        let _ = write!(buf, "{name}: {value}\r\n");
    }
    buf.extend_from_slice(b"\r\n");
    buf.extend_from_slice(&resp.body);
}

/// Assemble and send one response in a single `write` syscall.
fn write_response(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    resp: &HttpResponse,
    keep_alive: bool,
) -> std::io::Result<()> {
    render_response(buf, resp, keep_alive);
    let stream = reader.get_mut();
    stream.write_all(buf)?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::client::HttpClient;
    use std::io::Cursor;

    // ------------------------------------------------ parser (no I/O)

    fn head(text: &str) -> Result<Option<HttpRequest>, String> {
        read_head(&mut Cursor::new(text.as_bytes())).map_err(|e| format!("{}:{}", e.status, e.message))
    }

    #[test]
    fn parses_request_line_and_headers() {
        let req = head(
            "POST /v1/models/m:predict?debug=1 HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/models/m:predict");
        assert_eq!(req.query, "debug=1");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.header(":version"), Some("HTTP/1.1"));
        assert!(wants_keep_alive(&req));
    }

    #[test]
    fn clean_eof_and_malformed_lines() {
        assert_eq!(head("").unwrap(), None);
        assert!(head("GET\r\n\r\n").is_err());
        assert!(head("GET / HTTP/1.1 extra\r\n\r\n").is_err());
        assert!(head("GET / SPDY/3\r\n\r\n").is_err());
        assert!(head("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").is_err());
        // EOF mid-headers is an error, not a clean close.
        assert!(head("GET / HTTP/1.1\r\nHost: x\r\n").is_err());
    }

    #[test]
    fn header_limits_enforced() {
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_REQUEST_LINE));
        let err = head(&long).unwrap_err();
        assert!(err.starts_with("431"), "{err}");
        let many = format!(
            "GET / HTTP/1.1\r\n{}\r\n",
            (0..MAX_HEADERS + 1)
                .map(|i| format!("h{i}: v\r\n"))
                .collect::<String>()
        );
        let err = head(&many).unwrap_err();
        assert!(err.starts_with("431"), "{err}");
    }

    #[test]
    fn keep_alive_rules() {
        let mk = |version: &str, conn: Option<&str>| {
            let mut headers = vec![(":version".to_string(), version.to_string())];
            if let Some(c) = conn {
                headers.push(("connection".to_string(), c.to_string()));
            }
            HttpRequest {
                method: "GET".into(),
                path: "/".into(),
                query: String::new(),
                headers,
                body: Vec::new(),
            }
        };
        assert!(wants_keep_alive(&mk("HTTP/1.1", None)));
        assert!(!wants_keep_alive(&mk("HTTP/1.0", None)));
        assert!(!wants_keep_alive(&mk("HTTP/1.1", Some("close"))));
        assert!(wants_keep_alive(&mk("HTTP/1.0", Some("keep-alive"))));
    }

    #[test]
    fn body_content_length_and_chunked() {
        let req = head("POST / HTTP/1.1\r\nContent-Length: 5\r\n\r\n").unwrap().unwrap();
        let mut rest = Cursor::new(b"hellomore".to_vec());
        assert_eq!(read_body(&mut rest, &req).unwrap(), b"hello");

        let req = head("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n")
            .unwrap()
            .unwrap();
        let mut rest = Cursor::new(b"4\r\nwiki\r\n5;ext=1\r\npedia\r\n0\r\n\r\n".to_vec());
        assert_eq!(read_body(&mut rest, &req).unwrap(), b"wikipedia");
        // Bad chunk framing errors.
        let mut bad = Cursor::new(b"4\r\nwikiXX".to_vec());
        assert!(read_body(&mut bad, &req).is_err());
        let mut bad = Cursor::new(b"zz\r\n".to_vec());
        assert!(read_body(&mut bad, &req).is_err());
    }

    #[test]
    fn ambiguous_framing_rejected() {
        // Transfer-Encoding together with Content-Length (or
        // conflicting duplicate Content-Lengths) is the request-
        // smuggling precondition: reject, never resolve.
        let req = head(
            "POST / HTTP/1.1\r\nContent-Length: 4\r\nTransfer-Encoding: chunked\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        let mut rest = Cursor::new(b"0\r\n\r\n".to_vec());
        let e = read_body(&mut rest, &req).unwrap_err();
        assert_eq!(e.status, 400);
        let req = head("POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 9\r\n\r\n")
            .unwrap()
            .unwrap();
        let mut rest = Cursor::new(b"abcdefghi".to_vec());
        let e = read_body(&mut rest, &req).unwrap_err();
        assert_eq!(e.status, 400);
        // Identical duplicates are tolerated (merged).
        let req = head("POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 4\r\n\r\n")
            .unwrap()
            .unwrap();
        let mut rest = Cursor::new(b"abcdef".to_vec());
        assert_eq!(read_body(&mut rest, &req).unwrap(), b"abcd");
    }

    #[test]
    fn oversized_bodies_rejected_without_reading() {
        let req = head(&format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY + 1
        ))
        .unwrap()
        .unwrap();
        let mut rest = Cursor::new(Vec::new());
        let e = read_body(&mut rest, &req).unwrap_err();
        assert_eq!(e.status, 413);
    }

    // --------------------------------------------------- over TCP

    fn echo_server() -> Arc<HttpServer> {
        HttpServer::start(
            "127.0.0.1:0",
            Arc::new(|req: &HttpRequest| {
                HttpResponse::text(200, &format!("{} {} {}", req.method, req.path, req.body.len()))
            }),
        )
        .unwrap()
    }

    #[test]
    fn keep_alive_serves_sequential_requests() {
        let server = echo_server();
        let mut c = HttpClient::connect(&server.addr().to_string()).unwrap();
        let (status, body) = c.get("/a").unwrap();
        assert_eq!((status, body.as_slice()), (200, b"GET /a 0".as_slice()));
        // Same connection again (keep-alive) with a body.
        let (status, body) = c.post_json("/b", "{\"x\":1}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"POST /b 7");
        assert_eq!(server.requests_served(), 2);
        server.stop();
    }

    #[test]
    fn chunked_request_over_tcp() {
        let server = echo_server();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        // `Connection: close` so read_to_end below sees EOF after the
        // response instead of a kept-alive socket.
        s.write_all(
            b"POST /c HTTP/1.1\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n\
              3\r\nabc\r\n2\r\nde\r\n0\r\n\r\n",
        )
        .unwrap();
        let mut buf = Vec::new();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("HTTP/1.1 200"), "{line}");
        // Drain headers, then the body says 5 bytes arrived.
        loop {
            line.clear();
            r.read_line(&mut line).unwrap();
            if line == "\r\n" {
                break;
            }
        }
        r.read_to_end(&mut buf).ok();
        assert!(String::from_utf8_lossy(&buf).contains("POST /c 5"));
        server.stop();
    }

    #[test]
    fn extra_headers_and_degradation_reasons_emitted() {
        let server = HttpServer::start(
            "127.0.0.1:0",
            Arc::new(|_req: &HttpRequest| {
                HttpResponse::error(503, "overloaded").with_header("Retry-After", "2")
            }),
        )
        .unwrap();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        let mut text = String::new();
        BufReader::new(s).read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable"), "{text}");
        assert!(text.contains("Retry-After: 2\r\n"), "{text}");
        assert_eq!(reason(504), "Gateway Timeout");
        server.stop();
    }

    #[test]
    fn malformed_request_gets_400_and_close() {
        let server = echo_server();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(b"NOT-HTTP\r\n\r\n").unwrap();
        let mut text = String::new();
        BufReader::new(s).read_to_string(&mut text).unwrap(); // server closes
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        assert!(text.contains("{\"error\":"), "{text}");
        server.stop();
    }

    #[test]
    fn stop_then_connect_fails_eventually() {
        let server = echo_server();
        let addr = server.addr();
        server.stop();
        std::thread::sleep(std::time::Duration::from_millis(50));
        let ok = TcpStream::connect(addr)
            .map(|mut s| {
                let _ = s.write_all(b"GET / HTTP/1.1\r\n\r\n");
                let mut buf = [0u8; 16];
                matches!(s.read(&mut buf), Ok(n) if n > 0)
            })
            .unwrap_or(false);
        assert!(!ok, "server still serving after stop");
    }
}
