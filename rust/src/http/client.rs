//! Minimal blocking HTTP/1.1 client.
//!
//! Just enough to drive the gateway from tests, benches and examples
//! over a kept-alive connection: one request in flight at a time,
//! `Content-Length` responses (all this server ever sends). Not a
//! general-purpose client.

use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

pub struct HttpClient {
    reader: BufReader<TcpStream>,
    addr: String,
    /// Request-assembly scratch reused across calls.
    scratch: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(HttpClient {
            reader: BufReader::new(stream),
            addr: addr.to_string(),
            scratch: Vec::new(),
        })
    }

    pub fn get(&mut self, path: &str) -> Result<(u16, Vec<u8>)> {
        self.request("GET", path, None, &[])
    }

    pub fn delete(&mut self, path: &str) -> Result<(u16, Vec<u8>)> {
        self.request("DELETE", path, None, &[])
    }

    pub fn post_json(&mut self, path: &str, body: &str) -> Result<(u16, Vec<u8>)> {
        self.request("POST", path, Some("application/json"), body.as_bytes())
    }

    /// POST an `application/x-tensorserve` binary payload, also asking
    /// for a binary reply.
    pub fn post_binary(&mut self, path: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
        self.request_with(
            "POST",
            path,
            Some("application/x-tensorserve"),
            Some("application/x-tensorserve"),
            body,
        )
    }

    /// Issue one request on the kept-alive connection; returns
    /// `(status, body)`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        content_type: Option<&str>,
        body: &[u8],
    ) -> Result<(u16, Vec<u8>)> {
        self.request_with(method, path, content_type, None, body)
    }

    /// [`request`](Self::request) plus an explicit `Accept` header for
    /// egress-codec negotiation.
    pub fn request_with(
        &mut self,
        method: &str,
        path: &str,
        content_type: Option<&str>,
        accept: Option<&str>,
        body: &[u8],
    ) -> Result<(u16, Vec<u8>)> {
        self.scratch.clear();
        self.scratch
            .extend_from_slice(format!("{method} {path} HTTP/1.1\r\nHost: {}\r\n", self.addr).as_bytes());
        if let Some(ct) = content_type {
            self.scratch
                .extend_from_slice(format!("Content-Type: {ct}\r\n").as_bytes());
        }
        if let Some(a) = accept {
            self.scratch
                .extend_from_slice(format!("Accept: {a}\r\n").as_bytes());
        }
        self.scratch
            .extend_from_slice(format!("Content-Length: {}\r\n\r\n", body.len()).as_bytes());
        self.scratch.extend_from_slice(body);
        let stream = self.reader.get_mut();
        stream.write_all(&self.scratch)?;
        stream.flush()?;

        // Status line.
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("{}: connection closed mid-call", self.addr);
        }
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("malformed status line {line:?}"))?;
        // Headers; the server always frames with Content-Length.
        let mut content_length: Option<usize> = None;
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("{}: connection closed mid-headers", self.addr);
            }
            let trimmed = line.trim_end();
            if trimmed.is_empty() {
                break;
            }
            if let Some((name, value)) = trimmed.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse().ok();
                }
            }
        }
        let len = content_length.ok_or_else(|| anyhow!("response without content-length"))?;
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body)?;
        Ok((status, body))
    }
}
