//! The HTTP/REST gateway (paper §1: "flexible … in ways to integrate
//! with systems").
//!
//! A second, JSON data plane over the exact same
//! [`crate::server::builder::ServerCore`] the binary RPC server uses —
//! labels, signatures, batching and lifecycle come for free; only the
//! wire format differs. De Rosa et al. ("On the Cost of Model-Serving
//! Frameworks") show REST ingress is where naive serving stacks lose
//! most of their throughput, so the JSON path keeps the PR 1
//! zero-copy contract: instance rows decode straight into pooled
//! buffers and response tensors recycle right after serialization.
//!
//! * [`server`] — dependency-free threaded HTTP/1.1 server
//!   (keep-alive, content-length + chunked bodies, size limits).
//! * [`router`] — TF-Serving-style URL surface
//!   (`/v1/models/{name}[/versions/{v}|/labels/{l}]:predict|…`,
//!   metadata GETs, label DELETE, `/healthz`).
//! * [`codec`] — JSON row/column formats ⇄ [`crate::rpc::proto`]
//!   messages.
//! * [`wire`] — pluggable per-request codecs over [`codec`]: scalar
//!   JSON, a SWAR/SIMD JSON fast path, and the RPC plane's binary
//!   tensor framing as `application/x-tensorserve`, negotiated by
//!   `Content-Type`/`Accept`.
//! * [`expose`] — `/metrics` Prometheus-style text exposition from
//!   [`crate::util::metrics`].
//! * [`client`] — a minimal blocking client for tests, benches and
//!   examples.

pub mod client;
pub mod codec;
pub mod expose;
pub mod router;
pub mod server;
pub mod wire;
