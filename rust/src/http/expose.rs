//! `/metrics` text exposition.
//!
//! Snapshots process-level state (buffer pools, pooled bytes) into the
//! server's [`Registry`] gauges and renders everything in Prometheus
//! text format — the same registry the RPC `Status` dump reads, so
//! both planes report one set of numbers (request counters, per-API
//! latency summaries, `predict.batch_rows` batch-size stats, pool
//! hit/miss gauges).

use crate::server::builder::ServerCore;
use crate::util::pool::BufferPool;

/// Everything a scraper needs, as `tensorserve_*` metrics.
pub fn metrics_text(core: &ServerCore) -> String {
    BufferPool::global().export(&core.registry, "tensor_pool");
    BufferPool::global_i32().export(&core.registry, "tensor_pool_i32");
    core.registry
        .gauge("pooled_buffer_bytes")
        .set(crate::util::mem::pooled_buffer_bytes() as i64);
    let mut text = core.registry.render_prometheus("tensorserve");
    // Serving state is rendered fresh each scrape (never via
    // persistent gauges): a version that unloads simply stops
    // appearing, instead of reporting 1 forever.
    text.push_str("# TYPE tensorserve_serving gauge\n");
    for id in core.avm().basic().all_ready() {
        text.push_str(&format!(
            "tensorserve_serving{{model=\"{}\",version=\"{}\"}} 1\n",
            id.name.replace('\\', "\\\\").replace('"', "\\\""),
            id.version
        ));
    }
    text
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::builder::ModelServer;
    use crate::server::config::ServerConfig;

    #[test]
    fn exposition_covers_requests_pools_and_batch_sizes() {
        let server = ModelServer::start(ServerConfig {
            poll_interval: None,
            models: Vec::new(),
            ..Default::default()
        })
        .unwrap();
        let core = server.core();
        core.registry.counter("rpc.predict.requests").inc();
        core.registry.histogram("predict.batch_rows").record(4);
        let text = metrics_text(core);
        assert!(text.contains("tensorserve_rpc_predict_requests 1\n"), "{text}");
        assert!(text.contains("tensorserve_predict_batch_rows_count 1\n"), "{text}");
        assert!(text.contains("tensorserve_tensor_pool_hits"), "{text}");
        assert!(text.contains("tensorserve_pooled_buffer_bytes"), "{text}");
        server.stop();
    }

    #[test]
    fn serving_lines_track_the_ready_set() {
        use crate::base::servable::ServableId;
        use crate::runtime::artifacts::ArtifactSpec;
        use crate::runtime::hlo_servable::synthetic_loader;
        use std::time::Duration;
        let server = ModelServer::start(ServerConfig {
            poll_interval: None,
            models: Vec::new(),
            ..Default::default()
        })
        .unwrap();
        server
            .avm()
            .basic()
            .load_and_wait(
                ServableId::new("exp", 1),
                synthetic_loader(ArtifactSpec::synthetic_classifier("exp", 1, 4, 2)),
                Duration::from_secs(30),
            )
            .unwrap();
        let line = "tensorserve_serving{model=\"exp\",version=\"1\"} 1\n";
        assert!(metrics_text(server.core()).contains(line));
        // After unload the line disappears — no stale gauge.
        server
            .avm()
            .basic()
            .unload_and_wait(ServableId::new("exp", 1), Duration::from_secs(30))
            .unwrap();
        assert!(!metrics_text(server.core()).contains(line));
        server.stop();
    }
}
