//! Figure-1 integration: the full lifecycle chain over a live directory
//! tree — FileSystemSource → SourceRouter → platform adapters →
//! AspiredVersionsManager — including version discovery, multi-platform
//! serving, failure injection, and recovery.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tensorserve::base::aspired::{AspiredVersionsCallback, Source};
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::inference::table::{table_source_adapter, TableServable};
use tensorserve::lifecycle::basic_manager::{ManagerOptions, VersionRequest};
use tensorserve::lifecycle::harness::State;
use tensorserve::lifecycle::manager::{AspiredVersionsManager, AvmOptions};
use tensorserve::lifecycle::policy::AvailabilityPreservingPolicy;
use tensorserve::lifecycle::source::{FileSystemSource, ServingPolicy, WatchedServable};
use tensorserve::lifecycle::source_router::SourceRouter;
use tensorserve::runtime::artifacts::{artifacts_available, default_artifacts_root};
use tensorserve::runtime::hlo_servable::{hlo_source_adapter, HloServable};
use tensorserve::runtime::pjrt::XlaRuntime;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ts-it-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn copy_dir(src: &PathBuf, dst: &PathBuf) {
    std::fs::create_dir_all(dst).unwrap();
    for e in std::fs::read_dir(src).unwrap() {
        let e = e.unwrap();
        let to = dst.join(e.file_name());
        if e.path().is_dir() {
            copy_dir(&e.path(), &to);
        } else {
            std::fs::copy(e.path(), &to).unwrap();
        }
    }
}

/// Assemble the Figure-1 chain over `root` and return (source, avm).
fn chain(root: &PathBuf) -> (Arc<FileSystemSource>, Arc<AspiredVersionsManager>) {
    let avm = AspiredVersionsManager::new(
        Arc::new(AvailabilityPreservingPolicy),
        AvmOptions {
            manager: ManagerOptions { load_threads: 2, name: "it".into(), ..Default::default() },
            reconcile_interval: Some(Duration::from_millis(10)),
        },
    );
    let sniff = root.clone();
    let router = SourceRouter::<PathBuf>::new(2, move |name| {
        // TensorFlow-vs-BananaFlow split, sniffed from artifact layout.
        let base = sniff.join(name);
        let is_table = tensorserve::lifecycle::source::scan_versions(&base)
            .last()
            .map(|v| base.join(v.to_string()).join("table.json").exists())
            .unwrap_or(false);
        usize::from(is_table)
    });
    let hlo = hlo_source_adapter(XlaRuntime::shared().unwrap());
    let table = table_source_adapter();
    hlo.connect(Arc::clone(&avm) as Arc<dyn AspiredVersionsCallback<_>>);
    table.connect(Arc::clone(&avm) as Arc<dyn AspiredVersionsCallback<_>>);
    router.connect_port(0, hlo);
    router.connect_port(1, table);

    let mut source = FileSystemSource::new(
        vec![
            WatchedServable {
                name: "mlp_classifier".into(),
                base_path: root.join("mlp_classifier"),
                policy: ServingPolicy::Latest(1),
            },
            WatchedServable {
                name: "toy_table".into(),
                base_path: root.join("toy_table"),
                policy: ServingPolicy::Latest(1),
            },
        ],
        Some(Duration::from_millis(20)),
    );
    source.set_aspired_versions_callback(router);
    (source, avm)
}

fn wait_versions(avm: &Arc<AspiredVersionsManager>, name: &str, want: &[u64]) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if avm.basic().ready_versions(name) == want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "{name}: wanted {want:?}, have {:?}",
            avm.basic().ready_versions(name)
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn figure1_multi_platform_discovery_and_transitions() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let root = tmpdir("fig1");
    let art = default_artifacts_root();
    // Start with classifier v1 only + the table.
    copy_dir(&art.join("mlp_classifier").join("1"), &root.join("mlp_classifier").join("1"));
    copy_dir(&art.join("toy_table").join("1"), &root.join("toy_table").join("1"));

    let (_source, avm) = chain(&root);

    // Both platforms load through the same chain.
    wait_versions(&avm, "mlp_classifier", &[1]);
    wait_versions(&avm, "toy_table", &[1]);
    let h = avm
        .handle::<HloServable>("mlp_classifier", VersionRequest::Latest)
        .unwrap();
    assert_eq!(h.spec.version, 1);
    let out = h.run(&Tensor::zeros(vec![2, 32])).unwrap();
    assert_eq!(out[0].as_f32().unwrap().shape(), &[2, 4]);
    let t = avm
        .handle::<TableServable>("toy_table", VersionRequest::Latest)
        .unwrap();
    assert_eq!(t.lookup("3"), Some(&[3.0, 2.0][..]));

    // "A new version is written from training": v2 appears on storage.
    copy_dir(&art.join("mlp_classifier").join("2"), &root.join("mlp_classifier").join("2"));
    // Latest(1) policy: v2 replaces v1 (availability-preserving).
    wait_versions(&avm, "mlp_classifier", &[2]);
    assert_eq!(
        avm.handle::<HloServable>("mlp_classifier", VersionRequest::Latest)
            .unwrap()
            .spec
            .version,
        2
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn corrupt_version_quarantined_old_version_keeps_serving() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let root = tmpdir("corrupt");
    let art = default_artifacts_root();
    copy_dir(&art.join("mlp_classifier").join("1"), &root.join("mlp_classifier").join("1"));
    copy_dir(&art.join("toy_table").join("1"), &root.join("toy_table").join("1"));
    let (_source, avm) = chain(&root);
    wait_versions(&avm, "mlp_classifier", &[1]);

    // A corrupt v2 lands: spec.json present but HLO garbage.
    let bad = root.join("mlp_classifier").join("2");
    copy_dir(&art.join("mlp_classifier").join("2"), &bad);
    for b in [1, 4, 16, 64] {
        std::fs::write(bad.join(format!("model_b{b}.hlo.txt")), "corrupt!").unwrap();
    }
    // v2 must end in Error; v1 must keep serving (availability).
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let st = avm.monitor().state_of(&ServableId::new("mlp_classifier", 2));
        if matches!(st, Some(State::Error(_))) {
            break;
        }
        assert!(Instant::now() < deadline, "v2 never errored: {st:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(avm.basic().ready_versions("mlp_classifier"), vec![1]);
    assert!(avm
        .handle::<HloServable>("mlp_classifier", VersionRequest::Latest)
        .is_ok());

    // The fixed v3 arrives; it loads and replaces v1.
    copy_dir(&art.join("mlp_classifier").join("2"), &root.join("mlp_classifier").join("3"));
    wait_versions(&avm, "mlp_classifier", &[3]);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn handles_survive_unload_and_free_off_request_thread() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let root = tmpdir("handles");
    let art = default_artifacts_root();
    copy_dir(&art.join("mlp_classifier").join("1"), &root.join("mlp_classifier").join("1"));
    copy_dir(&art.join("toy_table").join("1"), &root.join("toy_table").join("1"));
    let (source, avm) = chain(&root);
    wait_versions(&avm, "mlp_classifier", &[1]);

    let h = avm
        .handle::<HloServable>("mlp_classifier", VersionRequest::Latest)
        .unwrap();
    // Unload everything (empty aspired set via policy change).
    source.set_policy("mlp_classifier", ServingPolicy::Specific(vec![]));
    source.poll_once();
    let deadline = Instant::now() + Duration::from_secs(60);
    while !avm.basic().ready_versions("mlp_classifier").is_empty() {
        assert!(Instant::now() < deadline);
        std::thread::sleep(Duration::from_millis(20));
    }
    // The checked-out handle still serves (refcounted, §2.1.2)...
    let out = h.run(&Tensor::zeros(vec![1, 32])).unwrap();
    assert_eq!(out[0].as_f32().unwrap().shape(), &[1, 4]);
    // ...and its final drop happens via the reclaim thread.
    drop(h);
    avm.basic().reclaimer().flush();
    let _ = std::fs::remove_dir_all(&root);
}
