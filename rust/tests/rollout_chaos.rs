//! Rollout chaos soak: the health-gated rollout loop closed end to
//! end, with NO manual controller verbs after `start_rollout`.
//!
//! * a healthy canary ramps, bakes, and promotes on its own;
//! * the next canary is broken (`exec:` faults scoped to THAT version
//!   only) — the windowed health gate auto-rolls it back and the
//!   reason lands in the rollout status;
//! * the faulted replicas' circuit breakers open under the error rate,
//!   then half-open-probe back to closed once the bad version is gone;
//! * a background client pinned to the `stable` label sees ZERO errors
//!   through all of it — version churn, forced replica churn, and
//!   autoscaler passes included.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
use tensorserve::base::tensor::Tensor;
use tensorserve::inference::ModelSpec;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::tfs2::autoscaler::AutoscalerConfig;
use tensorserve::tfs2::fleet::{Fleet, FleetConfig};
use tensorserve::tfs2::rollout::RolloutPolicy;
use tensorserve::tfs2::router::BreakerConfig;
use tensorserve::tfs2::store::Store;
use tensorserve::util::fault::{arm, reset, Fault};

/// The fault registry is process-global, so fault-using tests in this
/// binary run one at a time.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn lock_faults() -> std::sync::MutexGuard<'static, ()> {
    FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn synthetic_artifacts(root: &Path, model: &str, versions: &[u64]) -> u64 {
    let mut ram = 0;
    for &v in versions {
        let spec = ArtifactSpec::synthetic_multi_head(model, v, 8, 3);
        ram = spec.ram_estimate_bytes;
        spec.write_to(&root.join(model).join(v.to_string())).unwrap();
    }
    ram
}

fn temp_root(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ts-rollout-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn reconcile_until_ready(fleet: &Fleet, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let report = fleet.reconcile().unwrap();
        if report.ready >= want {
            return;
        }
        assert!(Instant::now() < deadline, "fleet never ready: {report:?}");
        std::thread::sleep(Duration::from_millis(100));
    }
}

fn predict(spec: ModelSpec) -> Request {
    Request::Predict {
        spec,
        signature: String::new(),
        inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
    }
}

/// The policy both phases run: one 50% step, short bake, tight error
/// gate. The latency gate is effectively off — synthetic versions have
/// identical cost, so only the error gate should ever fire here.
fn policy() -> RolloutPolicy {
    RolloutPolicy {
        canary_fraction_ramp: vec![0.5],
        bake_ms: 300,
        max_error_rate: 0.2,
        max_p99_vs_stable: 1e9,
        min_requests: 5,
    }
}

#[test]
fn churn_soak_promotes_healthy_canary_and_auto_rolls_back_broken_one() {
    let _guard = lock_faults();
    reset();
    let root = temp_root("soak");
    let ram = synthetic_artifacts(&root, "roll_m", &[1, 2, 3]);

    let fleet = Arc::new(
        Fleet::start(
            Store::in_memory(0),
            FleetConfig {
                jobs: 1,
                artifacts_root: root.clone(),
                hedge_delay: Duration::from_millis(25),
                // Rate-gate dominated: stable/canary traffic alternates,
                // so a consecutive-failure gate can never trip here; the
                // windowed error rate under a broken 50% canary (~half
                // of all attempts failing) must.
                breaker: BreakerConfig {
                    consecutive_failures: 50,
                    error_rate: 0.25,
                    min_requests: 5,
                    open_ms: 400,
                    window_ms: 1_000,
                },
                autoscaler: AutoscalerConfig { cooldown_ticks: 1, ..Default::default() },
                ..Default::default()
            },
        )
        .unwrap(),
    );
    fleet.deploy("roll_m", root.to_str().unwrap(), ram, 1).unwrap();
    reconcile_until_ready(&fleet, 1);
    fleet.set_label("roll_m", "stable", 1).unwrap();

    // Background client pinned to the stable label: it must never see
    // an error, through promotion, rollback, and replica churn alike.
    let stop = Arc::new(AtomicBool::new(false));
    let stable_ok = Arc::new(AtomicU64::new(0));
    let stable_errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let stable_client = {
        let (fleet, stop) = (Arc::clone(&fleet), Arc::clone(&stop));
        let (ok, errors) = (Arc::clone(&stable_ok), Arc::clone(&stable_errors));
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match fleet.router.route(&predict(ModelSpec::with_label("roll_m", "stable"))) {
                    Ok(Response::Predict { .. }) => {
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(other) => errors.lock().unwrap().push(format!("{other:?}")),
                    Err(e) => errors.lock().unwrap().push(format!("{e:#}")),
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        })
    };

    // Unlabeled traffic feeding the canary split + one full control-
    // plane tick (rollout evaluation AND an autoscaler pass).
    let tick = |fleet: &Fleet| -> String {
        for _ in 0..60 {
            let _ = fleet.router.route(&predict(ModelSpec::latest("roll_m")));
        }
        fleet.autoscale_once().unwrap();
        fleet.rollout_once().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        fleet.rollout_status("roll_m").unwrap()
    };

    // ---- Phase A: healthy canary v2 ramps, bakes, promotes. --------
    fleet.start_rollout("roll_m", 2, policy()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut churned = false;
    loop {
        let status = tick(&fleet);
        if status.starts_with("promoted") {
            break;
        }
        assert!(
            !status.starts_with("rolled_back"),
            "healthy canary rolled back: {status}"
        );
        // Replica churn mid-rollout: once traffic is ramping, grow the
        // job; the partially-loaded newcomer must not drop a request.
        if !churned && status.starts_with("ramping") {
            fleet.cluster.scale_to("job-0", 2).unwrap();
            churned = true;
        }
        assert!(Instant::now() < deadline, "rollout stuck: {status}");
    }
    assert!(churned, "rollout promoted before the churn step ran");
    assert_eq!(fleet.controller.desired_versions("roll_m").unwrap(), vec![2]);
    assert_eq!(fleet.controller.resolve_label("roll_m", "stable").unwrap(), 2);
    assert!(fleet.controller.resolve_label("roll_m", "canary").is_err());

    // ---- Phase B: v3 is broken — faults scoped to v3 ONLY. ---------
    arm(
        "exec:roll_m@v3",
        Fault::Fail { message: "v3 crashes on execute".into() },
        1_000_000,
    );
    fleet.start_rollout("roll_m", 3, policy()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut breaker_opened = false;
    let status = loop {
        let status = tick(&fleet);
        // The broken canary's failures push the per-replica windowed
        // error rate past the breaker gate before the rollout gate has
        // even scraped: catch the open state while the fault is live.
        for addr in fleet.cluster.replica_addrs("job-0") {
            if fleet.router.breaker_state(&addr) == Some("open") {
                breaker_opened = true;
            }
        }
        if status.starts_with("rolled_back") {
            break status;
        }
        assert!(!status.starts_with("promoted"), "broken canary promoted");
        assert!(Instant::now() < deadline, "rollback never happened: {status}");
    };
    // The gate, the version, and the reason all surface in the status.
    assert!(status.contains("error-rate"), "{status}");
    assert!(status.contains("v3"), "{status}");
    assert!(status.contains("stable v2 restored"), "{status}");
    assert!(breaker_opened, "no replica breaker opened under the broken canary");
    // Auto-rollback restored the stable desired set and pruned the
    // canary label — all without a single manual controller call.
    assert_eq!(fleet.controller.desired_versions("roll_m").unwrap(), vec![2]);
    assert_eq!(fleet.controller.resolve_label("roll_m", "stable").unwrap(), 2);
    assert!(fleet.controller.resolve_label("roll_m", "canary").is_err());

    // ---- Breaker recovery + scale back down. -----------------------
    // v3 is unloaded, so the (still-armed) fault never fires again:
    // open breakers must half-open-probe on live traffic and close.
    fleet.cluster.scale_to("job-0", 1).unwrap();
    fleet.reconcile().unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        for _ in 0..20 {
            let _ = fleet.router.route(&predict(ModelSpec::latest("roll_m")));
        }
        let healed = fleet
            .cluster
            .replica_addrs("job-0")
            .iter()
            .all(|a| matches!(fleet.router.breaker_state(a), None | Some("closed")));
        if healed {
            break;
        }
        assert!(Instant::now() < deadline, "breakers never closed again");
        std::thread::sleep(Duration::from_millis(50));
    }

    stop.store(true, Ordering::Relaxed);
    stable_client.join().unwrap();
    let errors = stable_errors.lock().unwrap();
    assert!(
        errors.is_empty(),
        "stable-label client saw {} errors, first: {}",
        errors.len(),
        errors[0]
    );
    assert!(
        stable_ok.load(Ordering::Relaxed) > 100,
        "stable-label client barely ran"
    );

    reset();
    fleet.stop();
    let _ = std::fs::remove_dir_all(&root);
}
