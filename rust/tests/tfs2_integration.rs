//! Figure-2 integration: the TFS² control plane (Controller →
//! Synchronizer → serving jobs → Router) over real sockets, including
//! canary/rollback commands, capacity-aware placement, store
//! durability, and hedged routing under an injected slow replica.

use std::sync::Arc;
use std::time::Duration;
use tensorserve::inference::example::{Example, Feature};
use tensorserve::rpc::client::ClientPool;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::{artifacts_available, default_artifacts_root, ArtifactSpec};
use tensorserve::tfs2::cluster::Cluster;
use tensorserve::tfs2::controller::Controller;
use tensorserve::tfs2::router::Router;
use tensorserve::tfs2::store::Store;
use tensorserve::tfs2::synchronizer::Synchronizer;

fn gaussian_examples(n: usize, seed: u64) -> Vec<Example> {
    let mut rng = tensorserve::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| {
            let x: Vec<f32> = (0..32).map(|_| rng.normal() as f32 * 2.0).collect();
            Example::new().with("x", Feature::Floats(x))
        })
        .collect()
}

fn sync_until(
    sync: &Synchronizer,
    controller: &Controller,
    router: &Router,
    want: usize,
) {
    let deadline = std::time::Instant::now() + Duration::from_secs(180);
    loop {
        let report = sync.sync_once(&controller.desired_state()).unwrap();
        let table = sync.routing_table();
        if report.ready >= want && table.len() >= want {
            router.update_table(table);
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "cluster never ready: {report:?}"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
}

#[test]
fn figure2_end_to_end_control_plane() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let artifacts = default_artifacts_root();
    let cluster = Cluster::start(2, 64 << 20, artifacts.clone()).unwrap();
    let store = Store::in_memory(1);
    let controller = Controller::new(Arc::clone(&store));
    let pool = Arc::new(ClientPool::new());
    let sync = Synchronizer::new(Arc::clone(&store), Arc::clone(&pool));
    let router = Router::new(Duration::from_millis(50));

    for (id, addr, cap) in cluster.jobs() {
        controller.register_job(&id, &addr, cap).unwrap();
    }

    // add model → placement → sync → route.
    let spec = ArtifactSpec::load(&artifacts.join("mlp_classifier").join("2")).unwrap();
    let job = controller
        .add_model(
            "mlp_classifier",
            artifacts.join("mlp_classifier").to_str().unwrap(),
            spec.ram_estimate_bytes,
            1,
        )
        .unwrap();
    assert!(job.starts_with("job-"));
    sync_until(&sync, &controller, &router, 1);

    let resp = router
        .route(&Request::classify("mlp_classifier", None, gaussian_examples(4, 1)))
        .unwrap();
    match resp {
        Response::Classify { model_version, classes, .. } => {
            assert_eq!(model_version, 1);
            assert_eq!(classes.len(), 4);
        }
        other => panic!("unexpected {other:?}"),
    }

    // canary: add v2 alongside v1; both must serve.
    controller.set_canary("mlp_classifier", true).unwrap();
    controller.add_version("mlp_classifier", 2).unwrap();
    assert_eq!(controller.desired_versions("mlp_classifier").unwrap(), vec![1, 2]);
    sync_until(&sync, &controller, &router, 1);
    for want_version in [1u64, 2] {
        let resp = router
            .route(&Request::classify(
                "mlp_classifier",
                Some(want_version),
                gaussian_examples(2, 2),
            ))
            .unwrap();
        match resp {
            Response::Classify { model_version, .. } => {
                assert_eq!(model_version, want_version)
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    // promote → only v2; rollback → only v1.
    controller.promote_canary("mlp_classifier").unwrap();
    sync_until(&sync, &controller, &router, 1);
    controller.rollback("mlp_classifier", 1).unwrap();
    assert_eq!(controller.desired_versions("mlp_classifier").unwrap(), vec![1]);
    sync_until(&sync, &controller, &router, 1);
    // v2 drains asynchronously after v1 is pinned; poll until the
    // latest-version route lands on v1.
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    loop {
        let resp = router
            .route(&Request::classify("mlp_classifier", None, gaussian_examples(1, 3)))
            .unwrap();
        match resp {
            Response::Classify { model_version: 1, .. } => break,
            Response::Classify { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        assert!(
            std::time::Instant::now() < deadline,
            "rollback never completed"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    cluster.stop();
}

#[test]
fn placement_respects_capacity_and_spreads() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let artifacts = default_artifacts_root();
    let store = Store::in_memory(0);
    let controller = Controller::new(Arc::clone(&store));
    // Tiny jobs: each fits exactly one model (~1.1MB estimates).
    controller.register_job("job-0", "", 2 << 20).unwrap();
    controller.register_job("job-1", "", 2 << 20).unwrap();

    let spec_c = ArtifactSpec::load(&artifacts.join("mlp_classifier").join("2")).unwrap();
    let spec_r = ArtifactSpec::load(&artifacts.join("mlp_regressor").join("2")).unwrap();
    let j1 = controller
        .add_model("mlp_classifier", "x", spec_c.ram_estimate_bytes, 1)
        .unwrap();
    let j2 = controller
        .add_model("mlp_regressor", "x", spec_r.ram_estimate_bytes, 1)
        .unwrap();
    assert_ne!(j1, j2, "second model must spill to the other job");
    // A third model does not fit anywhere.
    assert!(controller.add_model("third", "x", 2 << 20, 1).is_err());
}

#[test]
fn store_durability_survives_controller_restart() {
    let dir = std::env::temp_dir().join(format!("ts-tfs2-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("controller");
    {
        let store = Store::open(&path, 0).unwrap();
        let c = Controller::new(store);
        c.register_job("j", "addr:1", 100).unwrap();
        c.add_model("m", "/m", 50, 3).unwrap();
        c.set_canary("m", true).unwrap();
        c.add_version("m", 4).unwrap();
    } // process "dies"
    let store = Store::open(&path, 0).unwrap();
    let c = Controller::new(store);
    assert_eq!(c.desired_versions("m").unwrap(), vec![3, 4]);
    assert_eq!(c.placement("m"), Some("j".into()));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hedged_routing_masks_slow_replica() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // A fast real job + a blackholed "replica" (a bound-but-unserved
    // port responds to connect but never to requests... simplest: a
    // dead address fails fast, exercising failover; the slow-replica
    // latency shape is measured in benches/bench_hedging.rs).
    let artifacts = default_artifacts_root();
    let cluster = Cluster::start(1, 64 << 20, artifacts.clone()).unwrap();
    let pool = Arc::new(ClientPool::new());
    cluster
        .sync_replicas(
            &pool,
            "job-0",
            &[tensorserve::tfs2::controller::ModelAssignment {
                name: "mlp_regressor".into(),
                base_path: String::new(),
                versions: vec![2],
                labels: Vec::new(),
            }],
        )
        .unwrap();
    // Wait until loaded.
    let addr = cluster.replica_addrs("job-0")[0].clone();
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    loop {
        if let Ok(Response::ModelStatus { versions }) =
            pool.call(&addr, &Request::ModelStatus { model: "mlp_regressor".into() })
        {
            if versions.iter().any(|(v, s)| *v == 2 && s == "ready") {
                break;
            }
        }
        assert!(std::time::Instant::now() < deadline);
        std::thread::sleep(Duration::from_millis(50));
    }

    let router = Router::new(Duration::from_millis(30));
    // Dead primary, healthy backup: hedging must fail over.
    router.update_table(vec![(
        "mlp_regressor".into(),
        vec!["127.0.0.1:1".into(), addr],
    )]);
    let mut served = 0;
    for i in 0..6 {
        if let Ok(Response::Regress { .. }) = router.route(&Request::regress(
            "mlp_regressor",
            None,
            gaussian_examples(1, i),
        )) {
            served += 1;
        }
    }
    assert_eq!(served, 6, "hedged router failed to mask the dead replica");
    assert!(router.hedge_rate() > 0.0);
    cluster.stop();
}
