//! End-to-end tests for the HTTP/REST gateway: a real `ModelServer`
//! with both listeners up, a synthetic multi-head servable, and raw
//! HTTP against the REST surface — predict (row + column formats),
//! classify/regress, labeled addressing, metadata GETs, label DELETE,
//! health/metrics, and RPC-vs-REST parity on the same model.

use std::sync::Arc;
use std::time::Duration;
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::http::client::HttpClient;
use tensorserve::inference::ModelSpec;
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::runtime::hlo_servable::synthetic_loader;
use tensorserve::runtime::pjrt::OutTensor;
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::ServerConfig;
use tensorserve::util::json::Json;

/// A running server (RPC + REST) with synthetic "syn" versions loaded.
fn gateway_server(versions: &[u64]) -> Arc<ModelServer> {
    let server = ModelServer::start(ServerConfig {
        http_addr: Some("127.0.0.1:0".to_string()),
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        ..Default::default()
    })
    .unwrap();
    for &v in versions {
        server
            .avm()
            .basic()
            .load_and_wait(
                ServableId::new("syn", v),
                synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", v, 8, 3)),
                Duration::from_secs(30),
            )
            .unwrap();
    }
    server
}

fn http(server: &ModelServer) -> HttpClient {
    HttpClient::connect(&server.http_addr().unwrap().to_string()).unwrap()
}

fn json_of(body: &[u8]) -> Json {
    Json::parse(std::str::from_utf8(body).unwrap()).unwrap()
}

/// Two 8-wide rows used across the predict tests.
fn rows() -> Vec<Vec<f64>> {
    (0..2)
        .map(|i| (0..8).map(|j| ((i * 8 + j) as f64) * 0.125).collect())
        .collect()
}

fn rows_json() -> String {
    let rows: Vec<String> = rows()
        .iter()
        .map(|r| {
            format!(
                "[{}]",
                r.iter().map(f64::to_string).collect::<Vec<_>>().join(",")
            )
        })
        .collect();
    format!("[{}]", rows.join(","))
}

#[test]
fn predict_row_format_matches_binary_rpc() {
    let server = gateway_server(&[2]);
    let mut c = http(&server);

    let (status, body) =
        c.post_json("/v1/models/syn:predict", &format!("{{\"instances\": {}}}", rows_json()))
            .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    assert_eq!(json.get("model_version").unwrap().as_u64(), Some(2));
    let preds = json.get("predictions").unwrap().as_arr().unwrap();
    assert_eq!(preds.len(), 2);

    // The same rows over the binary RPC path must produce the same
    // numbers — one ServerCore, two wire formats.
    let tensor_rows: Vec<Vec<f32>> = rows()
        .iter()
        .map(|r| r.iter().map(|&x| x as f32).collect())
        .collect();
    let mut rpc = RpcClient::connect(&server.addr().to_string()).unwrap();
    let resp = rpc
        .call_ok(&Request::Predict {
            spec: ModelSpec::latest("syn"),
            signature: String::new(),
            inputs: vec![("x".into(), Tensor::matrix(tensor_rows).unwrap())],
        })
        .unwrap();
    let (rpc_log_probs, rpc_classes) = match resp {
        Response::Predict { outputs, .. } => {
            let lp = match &outputs[0] {
                (name, OutTensor::F32(t)) if name.as_str() == "log_probs" => t.clone(),
                other => panic!("unexpected {other:?}"),
            };
            let cl = match &outputs[1] {
                (name, OutTensor::I32(t)) if name.as_str() == "class" => t.clone(),
                other => panic!("unexpected {other:?}"),
            };
            (lp, cl)
        }
        other => panic!("unexpected {other:?}"),
    };
    for (i, pred) in preds.iter().enumerate() {
        assert_eq!(
            pred.get("class").unwrap().as_i64().unwrap() as i32,
            rpc_classes.data()[i],
            "row {i} class"
        );
        let http_lp: Vec<f64> = pred
            .get("log_probs")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (a, b) in http_lp.iter().zip(rpc_log_probs.row(i)) {
            assert!((a - *b as f64).abs() < 1e-6, "row {i}: {a} vs {b}");
        }
    }
    server.stop();
}

#[test]
fn predict_column_format_and_versioned_paths() {
    let server = gateway_server(&[1, 2]);
    let mut c = http(&server);

    // Column format: named tensor in, full tensors out.
    let (status, body) = c
        .post_json(
            "/v1/models/syn:predict",
            &format!("{{\"inputs\": {{\"x\": {}}}}}", rows_json()),
        )
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    let outs = json.get("outputs").unwrap();
    assert_eq!(outs.get("class").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(
        outs.get("log_probs").unwrap().as_arr().unwrap()[0]
            .as_arr()
            .unwrap()
            .len(),
        3
    );

    // A pinned version serves that version.
    let (status, body) = c
        .post_json(
            "/v1/models/syn/versions/1:predict",
            &format!("{{\"instances\": {}}}", rows_json()),
        )
        .unwrap();
    assert_eq!(status, 200);
    assert_eq!(json_of(&body).get("model_version").unwrap().as_u64(), Some(1));
    server.stop();
}

#[test]
fn labeled_paths_and_label_delete() {
    let server = gateway_server(&[1, 2]);
    // Labels attach through the admin RPC (same core).
    for (label, version) in [("stable", 1u64), ("canary", 2)] {
        match server.core().handle(Request::SetVersionLabel {
            model: "syn".into(),
            label: label.into(),
            version,
        }) {
            Response::Ack => {}
            other => panic!("unexpected {other:?}"),
        }
    }
    let mut c = http(&server);
    for (label, want) in [("stable", 1u64), ("canary", 2)] {
        let (status, body) = c
            .post_json(
                &format!("/v1/models/syn/labels/{label}:predict"),
                &format!("{{\"instances\": {}}}", rows_json()),
            )
            .unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        assert_eq!(
            json_of(&body).get("model_version").unwrap().as_u64(),
            Some(want),
            "label {label}"
        );
    }

    // DELETE the canary label; labeled lookups then 404.
    let (status, body) = c.delete("/v1/models/syn/labels/canary").unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    assert_eq!(json_of(&body).get("ok").unwrap().as_bool(), Some(true));
    let (status, body) = c
        .post_json(
            "/v1/models/syn/labels/canary:predict",
            &format!("{{\"instances\": {}}}", rows_json()),
        )
        .unwrap();
    assert_eq!(status, 404);
    assert!(json_of(&body).get("error").unwrap().as_str().unwrap().contains("canary"));
    // Deleting again: 404 with the error envelope.
    let (status, _) = c.delete("/v1/models/syn/labels/canary").unwrap();
    assert_eq!(status, 404);
    server.stop();
}

#[test]
fn classify_and_regress_routes() {
    let server = gateway_server(&[2]);
    let mut c = http(&server);
    let examples =
        r#"[{"x": [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]}, {"x": [1, 1, 1, 1, 1, 1, 1, 1]}]"#;

    let (status, body) = c
        .post_json(
            "/v1/models/syn:classify",
            &format!("{{\"examples\": {examples}, \"signature_name\": \"classify\"}}"),
        )
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    assert_eq!(json.get("classes").unwrap().as_arr().unwrap().len(), 2);
    let results = json.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].as_arr().unwrap().len(), 3); // 3 classes

    let (status, body) = c
        .post_json(
            "/v1/models/syn:regress",
            &format!("{{\"examples\": {examples}, \"signature_name\": \"regress\"}}"),
        )
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    assert_eq!(json.get("results").unwrap().as_arr().unwrap().len(), 2);

    // Wrong method for the signature is a 400 naming the mismatch.
    let (status, body) = c
        .post_json(
            "/v1/models/syn:regress",
            &format!("{{\"examples\": {examples}, \"signature_name\": \"classify\"}}"),
        )
        .unwrap();
    assert_eq!(status, 400);
    assert!(json_of(&body).get("error").unwrap().as_str().unwrap().contains("regress"));
    server.stop();
}

#[test]
fn metadata_health_metrics_and_errors() {
    let server = gateway_server(&[1, 2]);
    match server.core().handle(Request::SetVersionLabel {
        model: "syn".into(),
        label: "canary".into(),
        version: 2,
    }) {
        Response::Ack => {}
        other => panic!("unexpected {other:?}"),
    }
    let mut c = http(&server);

    // Health first.
    let (status, body) = c.get("/healthz").unwrap();
    assert_eq!((status, body.as_slice()), (200, b"ok\n".as_slice()));

    // Model status: per-version state + labels + signatures.
    let (status, body) = c.get("/v1/models/syn").unwrap();
    assert_eq!(status, 200);
    let json = json_of(&body);
    assert_eq!(json.get("model").unwrap().as_str(), Some("syn"));
    let versions = json.get("versions").unwrap().as_arr().unwrap();
    assert_eq!(versions.len(), 2);
    let v2 = versions
        .iter()
        .find(|v| v.get("version").unwrap().as_u64() == Some(2))
        .unwrap();
    assert_eq!(v2.get("state").unwrap().as_str(), Some("ready"));
    assert_eq!(
        v2.get("labels").unwrap(),
        &Json::Arr(vec![Json::str("canary")])
    );
    assert!(v2.get_path("signatures.serving_default").is_some());

    // Narrowed by label.
    let (status, body) = c.get("/v1/models/syn/labels/canary").unwrap();
    assert_eq!(status, 200);
    assert_eq!(
        json_of(&body).get("versions").unwrap().as_arr().unwrap().len(),
        1
    );

    // Error shapes: unknown model 404, unknown route 404, bad body
    // 400, bad shape 400 — all with the {"error": ...} envelope.
    let (status, body) = c.get("/v1/models/ghost").unwrap();
    assert_eq!(status, 404);
    assert!(json_of(&body).get("error").unwrap().as_str().unwrap().contains("ghost"));
    let (status, _) = c.get("/v1/other").unwrap();
    assert_eq!(status, 404);
    let (status, body) = c.post_json("/v1/models/syn:predict", "{not json").unwrap();
    assert_eq!(status, 400);
    assert!(json_of(&body).get("error").is_some());
    let (status, body) = c
        .post_json("/v1/models/syn:predict", r#"{"instances": [[1, 2]]}"#)
        .unwrap();
    assert_eq!(status, 400);
    assert!(
        json_of(&body).get("error").unwrap().as_str().unwrap().contains("'x'"),
        "validation error should name the tensor: {}",
        String::from_utf8_lossy(&body)
    );
    let (status, _) = c
        .request("PUT", "/v1/models/syn", Some("application/json"), b"{}")
        .unwrap();
    assert_eq!(status, 405);

    // /metrics exposes request counts and batch-size stats from the
    // traffic above (every request on this kept-alive connection).
    let (status, body) = c.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("tensorserve_http_requests"), "{text}");
    assert!(text.contains("tensorserve_rpc_predict_requests"), "{text}");
    assert!(text.contains("tensorserve_predict_batch_rows_count"), "{text}");
    assert!(text.contains("tensorserve_tensor_pool_hits"), "{text}");
    server.stop();
}

#[test]
fn models_listing_reports_states_and_labels() {
    let server = gateway_server(&[1, 2]);
    match server.core().handle(Request::SetVersionLabel {
        model: "syn".into(),
        label: "canary".into(),
        version: 2,
    }) {
        Response::Ack => {}
        other => panic!("unexpected {other:?}"),
    }
    let mut c = http(&server);

    let (status, body) = c.get("/v1/models").unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    let models = json.get("models").unwrap().as_arr().unwrap();
    let syn = models
        .iter()
        .find(|m| m.get("name").unwrap().as_str() == Some("syn"))
        .unwrap();
    let versions = syn.get("versions").unwrap().as_arr().unwrap();
    assert_eq!(versions.len(), 2);
    // Sorted by version, each with state + labels.
    assert_eq!(versions[0].get("version").unwrap().as_u64(), Some(1));
    assert_eq!(versions[0].get("state").unwrap().as_str(), Some("ready"));
    assert_eq!(versions[0].get("labels").unwrap(), &Json::Arr(vec![]));
    assert_eq!(versions[1].get("version").unwrap().as_u64(), Some(2));
    assert_eq!(
        versions[1].get("labels").unwrap(),
        &Json::Arr(vec![Json::str("canary")])
    );
    // The listing has no signature payloads — that's the per-model GET.
    assert!(versions[1].get("signatures").is_none());
    server.stop();
}

#[test]
fn gateway_survives_concurrent_clients() {
    let server = gateway_server(&[2]);
    let addr = server.http_addr().unwrap().to_string();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = HttpClient::connect(&addr).unwrap();
                for _ in 0..25 {
                    let (status, body) = c
                        .post_json(
                            "/v1/models/syn:predict",
                            &format!("{{\"instances\": {}}}", rows_json()),
                        )
                        .unwrap();
                    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.stop();
}
