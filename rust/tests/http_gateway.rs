//! End-to-end tests for the HTTP/REST gateway: a real `ModelServer`
//! with both listeners up, a synthetic multi-head servable, and raw
//! HTTP against the REST surface — predict (row + column formats),
//! classify/regress, labeled addressing, metadata GETs, label DELETE,
//! health/metrics, and RPC-vs-REST parity on the same model.

use std::sync::Arc;
use std::time::Duration;
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::http::client::HttpClient;
use tensorserve::inference::ModelSpec;
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{encode_predict_payload, Request, Response};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::runtime::hlo_servable::synthetic_loader;
use tensorserve::runtime::pjrt::OutTensor;
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::ServerConfig;
use tensorserve::util::json::Json;

/// A running server (RPC + REST) with synthetic "syn" versions loaded.
fn gateway_server(versions: &[u64]) -> Arc<ModelServer> {
    let server = ModelServer::start(ServerConfig {
        http_addr: Some("127.0.0.1:0".to_string()),
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        ..Default::default()
    })
    .unwrap();
    for &v in versions {
        server
            .avm()
            .basic()
            .load_and_wait(
                ServableId::new("syn", v),
                synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", v, 8, 3)),
                Duration::from_secs(30),
            )
            .unwrap();
    }
    server
}

fn http(server: &ModelServer) -> HttpClient {
    HttpClient::connect(&server.http_addr().unwrap().to_string()).unwrap()
}

fn json_of(body: &[u8]) -> Json {
    Json::parse(std::str::from_utf8(body).unwrap()).unwrap()
}

/// Two 8-wide rows used across the predict tests.
fn rows() -> Vec<Vec<f64>> {
    (0..2)
        .map(|i| (0..8).map(|j| ((i * 8 + j) as f64) * 0.125).collect())
        .collect()
}

fn rows_json() -> String {
    let rows: Vec<String> = rows()
        .iter()
        .map(|r| {
            format!(
                "[{}]",
                r.iter().map(f64::to_string).collect::<Vec<_>>().join(",")
            )
        })
        .collect();
    format!("[{}]", rows.join(","))
}

#[test]
fn predict_row_format_matches_binary_rpc() {
    let server = gateway_server(&[2]);
    let mut c = http(&server);

    let (status, body) =
        c.post_json("/v1/models/syn:predict", &format!("{{\"instances\": {}}}", rows_json()))
            .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    assert_eq!(json.get("model_version").unwrap().as_u64(), Some(2));
    let preds = json.get("predictions").unwrap().as_arr().unwrap();
    assert_eq!(preds.len(), 2);

    // The same rows over the binary RPC path must produce the same
    // numbers — one ServerCore, two wire formats.
    let tensor_rows: Vec<Vec<f32>> = rows()
        .iter()
        .map(|r| r.iter().map(|&x| x as f32).collect())
        .collect();
    let mut rpc = RpcClient::connect(&server.addr().to_string()).unwrap();
    let resp = rpc
        .call_ok(&Request::Predict {
            spec: ModelSpec::latest("syn"),
            signature: String::new(),
            inputs: vec![("x".into(), Tensor::matrix(tensor_rows).unwrap())],
        })
        .unwrap();
    let (rpc_log_probs, rpc_classes) = match resp {
        Response::Predict { outputs, .. } => {
            let lp = match &outputs[0] {
                (name, OutTensor::F32(t)) if name.as_str() == "log_probs" => t.clone(),
                other => panic!("unexpected {other:?}"),
            };
            let cl = match &outputs[1] {
                (name, OutTensor::I32(t)) if name.as_str() == "class" => t.clone(),
                other => panic!("unexpected {other:?}"),
            };
            (lp, cl)
        }
        other => panic!("unexpected {other:?}"),
    };
    for (i, pred) in preds.iter().enumerate() {
        assert_eq!(
            pred.get("class").unwrap().as_i64().unwrap() as i32,
            rpc_classes.data()[i],
            "row {i} class"
        );
        let http_lp: Vec<f64> = pred
            .get("log_probs")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (a, b) in http_lp.iter().zip(rpc_log_probs.row(i)) {
            assert!((a - *b as f64).abs() < 1e-6, "row {i}: {a} vs {b}");
        }
    }
    server.stop();
}

#[test]
fn predict_column_format_and_versioned_paths() {
    let server = gateway_server(&[1, 2]);
    let mut c = http(&server);

    // Column format: named tensor in, full tensors out.
    let (status, body) = c
        .post_json(
            "/v1/models/syn:predict",
            &format!("{{\"inputs\": {{\"x\": {}}}}}", rows_json()),
        )
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    let outs = json.get("outputs").unwrap();
    assert_eq!(outs.get("class").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(
        outs.get("log_probs").unwrap().as_arr().unwrap()[0]
            .as_arr()
            .unwrap()
            .len(),
        3
    );

    // A pinned version serves that version.
    let (status, body) = c
        .post_json(
            "/v1/models/syn/versions/1:predict",
            &format!("{{\"instances\": {}}}", rows_json()),
        )
        .unwrap();
    assert_eq!(status, 200);
    assert_eq!(json_of(&body).get("model_version").unwrap().as_u64(), Some(1));
    server.stop();
}

#[test]
fn labeled_paths_and_label_delete() {
    let server = gateway_server(&[1, 2]);
    // Labels attach through the admin RPC (same core).
    for (label, version) in [("stable", 1u64), ("canary", 2)] {
        match server.core().handle(Request::SetVersionLabel {
            model: "syn".into(),
            label: label.into(),
            version,
        }) {
            Response::Ack => {}
            other => panic!("unexpected {other:?}"),
        }
    }
    let mut c = http(&server);
    for (label, want) in [("stable", 1u64), ("canary", 2)] {
        let (status, body) = c
            .post_json(
                &format!("/v1/models/syn/labels/{label}:predict"),
                &format!("{{\"instances\": {}}}", rows_json()),
            )
            .unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        assert_eq!(
            json_of(&body).get("model_version").unwrap().as_u64(),
            Some(want),
            "label {label}"
        );
    }

    // DELETE the canary label; labeled lookups then 404.
    let (status, body) = c.delete("/v1/models/syn/labels/canary").unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    assert_eq!(json_of(&body).get("ok").unwrap().as_bool(), Some(true));
    let (status, body) = c
        .post_json(
            "/v1/models/syn/labels/canary:predict",
            &format!("{{\"instances\": {}}}", rows_json()),
        )
        .unwrap();
    assert_eq!(status, 404);
    assert!(json_of(&body).get("error").unwrap().as_str().unwrap().contains("canary"));
    // Deleting again: 404 with the error envelope.
    let (status, _) = c.delete("/v1/models/syn/labels/canary").unwrap();
    assert_eq!(status, 404);
    server.stop();
}

#[test]
fn classify_and_regress_routes() {
    let server = gateway_server(&[2]);
    let mut c = http(&server);
    let examples =
        r#"[{"x": [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]}, {"x": [1, 1, 1, 1, 1, 1, 1, 1]}]"#;

    let (status, body) = c
        .post_json(
            "/v1/models/syn:classify",
            &format!("{{\"examples\": {examples}, \"signature_name\": \"classify\"}}"),
        )
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    assert_eq!(json.get("classes").unwrap().as_arr().unwrap().len(), 2);
    let results = json.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].as_arr().unwrap().len(), 3); // 3 classes

    let (status, body) = c
        .post_json(
            "/v1/models/syn:regress",
            &format!("{{\"examples\": {examples}, \"signature_name\": \"regress\"}}"),
        )
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    assert_eq!(json.get("results").unwrap().as_arr().unwrap().len(), 2);

    // Wrong method for the signature is a 400 naming the mismatch.
    let (status, body) = c
        .post_json(
            "/v1/models/syn:regress",
            &format!("{{\"examples\": {examples}, \"signature_name\": \"classify\"}}"),
        )
        .unwrap();
    assert_eq!(status, 400);
    assert!(json_of(&body).get("error").unwrap().as_str().unwrap().contains("regress"));
    server.stop();
}

#[test]
fn metadata_health_metrics_and_errors() {
    let server = gateway_server(&[1, 2]);
    match server.core().handle(Request::SetVersionLabel {
        model: "syn".into(),
        label: "canary".into(),
        version: 2,
    }) {
        Response::Ack => {}
        other => panic!("unexpected {other:?}"),
    }
    let mut c = http(&server);

    // Health first.
    let (status, body) = c.get("/healthz").unwrap();
    assert_eq!((status, body.as_slice()), (200, b"ok\n".as_slice()));

    // Model status: per-version state + labels + signatures.
    let (status, body) = c.get("/v1/models/syn").unwrap();
    assert_eq!(status, 200);
    let json = json_of(&body);
    assert_eq!(json.get("model").unwrap().as_str(), Some("syn"));
    let versions = json.get("versions").unwrap().as_arr().unwrap();
    assert_eq!(versions.len(), 2);
    let v2 = versions
        .iter()
        .find(|v| v.get("version").unwrap().as_u64() == Some(2))
        .unwrap();
    assert_eq!(v2.get("state").unwrap().as_str(), Some("ready"));
    assert_eq!(
        v2.get("labels").unwrap(),
        &Json::Arr(vec![Json::str("canary")])
    );
    assert!(v2.get_path("signatures.serving_default").is_some());

    // Narrowed by label.
    let (status, body) = c.get("/v1/models/syn/labels/canary").unwrap();
    assert_eq!(status, 200);
    assert_eq!(
        json_of(&body).get("versions").unwrap().as_arr().unwrap().len(),
        1
    );

    // Error shapes: unknown model 404, unknown route 404, bad body
    // 400, bad shape 400 — all with the {"error": ...} envelope.
    let (status, body) = c.get("/v1/models/ghost").unwrap();
    assert_eq!(status, 404);
    assert!(json_of(&body).get("error").unwrap().as_str().unwrap().contains("ghost"));
    let (status, _) = c.get("/v1/other").unwrap();
    assert_eq!(status, 404);
    let (status, body) = c.post_json("/v1/models/syn:predict", "{not json").unwrap();
    assert_eq!(status, 400);
    assert!(json_of(&body).get("error").is_some());
    let (status, body) = c
        .post_json("/v1/models/syn:predict", r#"{"instances": [[1, 2]]}"#)
        .unwrap();
    assert_eq!(status, 400);
    assert!(
        json_of(&body).get("error").unwrap().as_str().unwrap().contains("'x'"),
        "validation error should name the tensor: {}",
        String::from_utf8_lossy(&body)
    );
    let (status, _) = c
        .request("PUT", "/v1/models/syn", Some("application/json"), b"{}")
        .unwrap();
    assert_eq!(status, 405);

    // /metrics exposes request counts and batch-size stats from the
    // traffic above (every request on this kept-alive connection).
    let (status, body) = c.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("tensorserve_http_requests"), "{text}");
    assert!(text.contains("tensorserve_rpc_predict_requests"), "{text}");
    assert!(text.contains("tensorserve_predict_batch_rows_count"), "{text}");
    assert!(text.contains("tensorserve_tensor_pool_hits"), "{text}");
    server.stop();
}

#[test]
fn models_listing_reports_states_and_labels() {
    let server = gateway_server(&[1, 2]);
    match server.core().handle(Request::SetVersionLabel {
        model: "syn".into(),
        label: "canary".into(),
        version: 2,
    }) {
        Response::Ack => {}
        other => panic!("unexpected {other:?}"),
    }
    let mut c = http(&server);

    let (status, body) = c.get("/v1/models").unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let json = json_of(&body);
    let models = json.get("models").unwrap().as_arr().unwrap();
    let syn = models
        .iter()
        .find(|m| m.get("name").unwrap().as_str() == Some("syn"))
        .unwrap();
    let versions = syn.get("versions").unwrap().as_arr().unwrap();
    assert_eq!(versions.len(), 2);
    // Sorted by version, each with state + labels.
    assert_eq!(versions[0].get("version").unwrap().as_u64(), Some(1));
    assert_eq!(versions[0].get("state").unwrap().as_str(), Some("ready"));
    assert_eq!(versions[0].get("labels").unwrap(), &Json::Arr(vec![]));
    assert_eq!(versions[1].get("version").unwrap().as_u64(), Some(2));
    assert_eq!(
        versions[1].get("labels").unwrap(),
        &Json::Arr(vec![Json::str("canary")])
    );
    // The listing has no signature payloads — that's the per-model GET.
    assert!(versions[1].get("signatures").is_none());
    server.stop();
}

/// POST `body` with `Transfer-Encoding: chunked`, split into
/// `chunk`-byte pieces so chunk boundaries land everywhere — including
/// mid-number, mid-escape, and mid-UTF-8-sequence for small strides.
fn post_chunked(addr: &str, path: &str, body: &[u8], chunk: usize) -> (u16, Vec<u8>) {
    use std::io::{BufRead, BufReader, Read, Write};
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )
    .into_bytes();
    for piece in body.chunks(chunk.max(1)) {
        req.extend_from_slice(format!("{:x}\r\n", piece.len()).as_bytes());
        req.extend_from_slice(piece);
        req.extend_from_slice(b"\r\n");
    }
    req.extend_from_slice(b"0\r\n\r\n");
    stream.write_all(&req).unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut content_length = 0usize;
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap();
            }
        }
    }
    let mut resp = vec![0u8; content_length];
    reader.read_exact(&mut resp).unwrap();
    (status, resp)
}

#[test]
fn content_type_negotiation_415_and_accept_406() {
    let server = gateway_server(&[2]);
    let mut c = http(&server);
    let body = format!("{{\"instances\": {}}}", rows_json());

    // Unknown Content-Type on a data-plane POST: 415 with the uniform
    // JSON error envelope, naming the offending type.
    let (status, resp) = c
        .request("POST", "/v1/models/syn:predict", Some("text/csv"), body.as_bytes())
        .unwrap();
    assert_eq!(status, 415, "{}", String::from_utf8_lossy(&resp));
    let err = json_of(&resp);
    assert!(
        err.get("error").unwrap().as_str().unwrap().contains("text/csv"),
        "{err:?}"
    );

    // An Accept list with nothing the gateway can produce: 406, same
    // envelope shape.
    let (status, resp) = c
        .request_with(
            "POST",
            "/v1/models/syn:predict",
            Some("application/json"),
            Some("application/msgpack"),
            body.as_bytes(),
        )
        .unwrap();
    assert_eq!(status, 406, "{}", String::from_utf8_lossy(&resp));
    assert!(json_of(&resp).get("error").is_some());

    // The scalar-codec escape hatch plus a wildcard Accept both
    // negotiate fine.
    let (status, resp) = c
        .request_with(
            "POST",
            "/v1/models/syn:predict",
            Some("application/json; codec=scalar"),
            Some("*/*"),
            body.as_bytes(),
        )
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    assert!(json_of(&resp).get("predictions").is_some());

    // An unknown codec= parameter is a negotiation failure, not a
    // silent fallback.
    let (status, resp) = c
        .request(
            "POST",
            "/v1/models/syn:predict",
            Some("application/json; codec=protobuf"),
            body.as_bytes(),
        )
        .unwrap();
    assert_eq!(status, 415, "{}", String::from_utf8_lossy(&resp));

    // Negotiation is scoped to data-plane POSTs: a metadata GET with an
    // exotic Accept still answers JSON.
    let (status, resp) = c
        .request_with("GET", "/v1/models/syn", None, Some("application/msgpack"), &[])
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    server.stop();
}

#[test]
fn binary_rest_content_type_matches_json_predict() {
    let server = gateway_server(&[2]);
    let mut c = http(&server);

    // JSON column-format reference answer (keys outputs by name, the
    // same shape the binary path produces).
    let (status, jbody) = c
        .post_json(
            "/v1/models/syn:predict",
            &format!("{{\"inputs\": {{\"x\": {}}}}}", rows_json()),
        )
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&jbody));
    let jout = json_of(&jbody);
    let jlp: Vec<f64> = jout
        .get("outputs")
        .unwrap()
        .get("log_probs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .flat_map(|row| {
            row.as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect::<Vec<_>>()
        })
        .collect();

    // The same rows as an application/x-tensorserve payload: binary in,
    // binary out, decoded with the RPC plane's own Response::decode.
    let tensor_rows: Vec<Vec<f32>> = rows()
        .iter()
        .map(|r| r.iter().map(|&x| x as f32).collect())
        .collect();
    let mut payload = Vec::new();
    encode_predict_payload(
        &mut payload,
        "",
        &[("x".into(), Tensor::matrix(tensor_rows).unwrap())],
    );
    let (status, bbody) = c.post_binary("/v1/models/syn:predict", &payload).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&bbody));
    match Response::decode(&bbody).unwrap() {
        Response::Predict { model_version, outputs } => {
            assert_eq!(model_version, 2);
            let lp = outputs
                .iter()
                .find_map(|(name, t)| match t {
                    OutTensor::F32(t) if name == "log_probs" => Some(t.clone()),
                    _ => None,
                })
                .unwrap();
            assert_eq!(lp.data().len(), jlp.len());
            for (a, b) in lp.data().iter().zip(&jlp) {
                assert!((*a as f64 - b).abs() < 1e-6, "{a} vs {b}");
            }
        }
        other => panic!("unexpected {other:?}"),
    }

    // Binary ingress with a JSON Accept crosses codecs: same model,
    // column-format JSON reply.
    let (status, xbody) = c
        .request_with(
            "POST",
            "/v1/models/syn:predict",
            Some("application/x-tensorserve"),
            Some("application/json"),
            &payload,
        )
        .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&xbody));
    let xout = json_of(&xbody);
    assert!(xout.get("outputs").unwrap().get("class").is_some());

    // A garbage binary body is a 400 with the JSON error envelope, not
    // a hang or a binary error blob.
    let (status, resp) = c
        .post_binary("/v1/models/syn:predict", &[0xff, 0xff, 0xff, 0xff, 1, 2])
        .unwrap();
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&resp));
    assert!(json_of(&resp).get("error").is_some());
    server.stop();
}

#[test]
fn chunked_bodies_decode_identically_to_unchunked() {
    let server = gateway_server(&[2]);
    let addr = server.http_addr().unwrap().to_string();
    let mut c = http(&server);

    // Chunk boundaries mid-number: 1-byte chunks split every float
    // literal; the larger strides hit other offsets.
    let plain = format!("{{\"instances\": {}}}", rows_json());
    let (ustatus, ubody) = c.post_json("/v1/models/syn:predict", &plain).unwrap();
    assert_eq!(ustatus, 200, "{}", String::from_utf8_lossy(&ubody));
    for chunk in [1, 3, 7, 64] {
        let (status, body) = post_chunked(&addr, "/v1/models/syn:predict", plain.as_bytes(), chunk);
        assert_eq!((status, &body), (ustatus, &ubody), "chunk size {chunk}");
    }

    // Chunk boundaries mid-escape: the unicode escape decodes to an
    // underscore, so this names the real serving_default signature and
    // must answer exactly like the unescaped body.
    let escaped = format!(
        "{{\"signature_name\": \"serving\\u005Fdefault\", \"instances\": {}}}",
        rows_json()
    );
    let named = format!(
        "{{\"signature_name\": \"serving_default\", \"instances\": {}}}",
        rows_json()
    );
    let (estatus, ebody) = c.post_json("/v1/models/syn:predict", &named).unwrap();
    assert_eq!(estatus, 200, "{}", String::from_utf8_lossy(&ebody));
    for chunk in [1, 5] {
        let (status, body) =
            post_chunked(&addr, "/v1/models/syn:predict", escaped.as_bytes(), chunk);
        assert_eq!((status, &body), (estatus, &ebody), "chunk size {chunk}");
    }

    // Chunk boundaries mid-UTF-8-sequence: the snowman is three bytes,
    // so 1- and 2-byte chunks split it. The signature doesn't exist, so
    // both paths answer the same error, byte for byte.
    let snowman = format!(
        "{{\"signature_name\": \"sn\u{2603}w\", \"instances\": {}}}",
        rows_json()
    );
    let (sstatus, sbody) = c.post_json("/v1/models/syn:predict", &snowman).unwrap();
    assert!(sstatus >= 400, "{}", String::from_utf8_lossy(&sbody));
    assert!(json_of(&sbody).get("error").is_some());
    for chunk in [1, 2] {
        let (status, body) =
            post_chunked(&addr, "/v1/models/syn:predict", snowman.as_bytes(), chunk);
        assert_eq!((status, &body), (sstatus, &sbody), "chunk size {chunk}");
    }
    server.stop();
}

#[test]
fn gateway_survives_concurrent_clients() {
    let server = gateway_server(&[2]);
    let addr = server.http_addr().unwrap().to_string();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = HttpClient::connect(&addr).unwrap();
                for _ in 0..25 {
                    let (status, body) = c
                        .post_json(
                            "/v1/models/syn:predict",
                            &format!("{{\"instances\": {}}}", rows_json()),
                        )
                        .unwrap();
                    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.stop();
}
