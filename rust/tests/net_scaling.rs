//! The net subsystem's end-to-end guarantees, over the real server:
//!
//! * 1000+ concurrent keep-alive connections on both wire planes with
//!   thread count O(reactor_threads + worker_threads) — the reactor's
//!   reason to exist.
//! * Slow-loris and idle connections are swept at `idle_timeout_ms`.
//! * Over-`max_connections` connects are answered with an immediate
//!   503 / `Unavailable` reject, never silently dropped.
//! * `stop()` drains: an in-flight request admitted before the stop
//!   still gets its reply before the listeners go away.
//! * Threaded mode (the legacy path) still serves, and its `stop()`
//!   joins every connection thread promptly (the detached-spawn bug).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};
use tensorserve::base::error::ErrorKind;
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::inference::ModelSpec;
use tensorserve::net::sys::{process_thread_count, raise_nofile_limit};
use tensorserve::net::{NetConfig, NetMode};
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::runtime::hlo_servable::synthetic_loader;
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::ServerConfig;

/// A server with no models, both planes listening, and the given net
/// knobs. Everything else is the test default.
fn server_with(net: NetConfig) -> std::sync::Arc<ModelServer> {
    ModelServer::start(ServerConfig {
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        http_addr: Some("127.0.0.1:0".into()),
        net,
        ..Default::default()
    })
    .unwrap()
}

fn load_synthetic(server: &ModelServer, name: &str) {
    server
        .avm()
        .basic()
        .load_and_wait(
            ServableId::new(name, 1),
            synthetic_loader(ArtifactSpec::synthetic_multi_head(name, 1, 8, 3)),
            Duration::from_secs(30),
        )
        .unwrap();
}

/// One keep-alive GET round trip: write the request, read exactly one
/// response (headers + Content-Length body), leave the stream open.
fn http_get(stream: &mut TcpStream, path: &str) -> String {
    write!(stream, "GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos + 4;
        }
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "EOF mid-response after {} bytes", buf.len());
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let body_len: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().unwrap())
        })
        .unwrap_or(0);
    while buf.len() < head_end + body_len {
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "EOF mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    String::from_utf8_lossy(&buf).to_string()
}

/// Read until EOF (or panic at `deadline`); returns the bytes seen.
/// Used to observe server-initiated closes (idle sweep, reject).
fn read_to_eof_by(stream: &mut TcpStream, deadline: Instant, what: &str) -> Vec<u8> {
    stream
        .set_read_timeout(Some(Duration::from_millis(250)))
        .unwrap();
    let mut got = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => return got,
            Ok(n) => got.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                assert!(Instant::now() < deadline, "{what}: no close before deadline");
            }
            // The server may RST a rejected/swept connection.
            Err(_) => return got,
        }
    }
}

/// Poll the shared registry's `net.connections_active` gauge until it
/// reaches `want` (accepts are asynchronous to client `connect()`).
fn wait_active(server: &ModelServer, want: i64) {
    let gauge = server.registry().gauge("net.connections_active");
    let deadline = Instant::now() + Duration::from_secs(10);
    while gauge.get() < want {
        assert!(
            Instant::now() < deadline,
            "never reached {want} active connections (at {})",
            gauge.get()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The headline guarantee: 1000+ keep-alive connections across both
/// planes, every one served twice, while the process grows by
/// O(reactor_threads + worker_threads) threads — not O(connections).
#[test]
fn thousand_keepalive_connections_with_bounded_threads() {
    // Client + server fds both live in this process: ~2 fds per
    // connection plus generous headroom.
    let limit = raise_nofile_limit(8192);
    if limit < 2500 {
        eprintln!("skipping: nofile limit {limit} too low for 1000 connections");
        return;
    }
    let server = server_with(NetConfig {
        reactor_threads: 2,
        worker_threads: 8,
        ..Default::default()
    });
    let threads_before = process_thread_count();

    const RPC_CONNS: usize = 500;
    const HTTP_CONNS: usize = 500;
    let rpc_addr = server.addr().to_string();
    let http_addr = server.http_addr().unwrap().to_string();

    // Open in paced chunks so the accept loop keeps up with the
    // listener backlog (a thundering-herd connect would otherwise see
    // SYN retransmit stalls, not a server defect).
    let mut rpc_clients = Vec::with_capacity(RPC_CONNS);
    let mut http_conns = Vec::with_capacity(HTTP_CONNS);
    for i in 0..RPC_CONNS.max(HTTP_CONNS) {
        if i < RPC_CONNS {
            rpc_clients.push(RpcClient::connect(&rpc_addr).unwrap());
        }
        if i < HTTP_CONNS {
            let s = TcpStream::connect(&http_addr).unwrap();
            s.set_nodelay(true).unwrap();
            http_conns.push(s);
        }
        if i % 100 == 99 {
            wait_active(&server, (rpc_clients.len() + http_conns.len()) as i64);
        }
    }
    wait_active(&server, (RPC_CONNS + HTTP_CONNS) as i64);

    // Two full rounds over every connection: proves each one is a
    // live keep-alive session, not a connect-per-request.
    for round in 0..2 {
        for c in rpc_clients.iter_mut() {
            assert!(matches!(
                c.call_ok(&Request::Ping).unwrap(),
                Response::Pong
            ));
        }
        for s in http_conns.iter_mut() {
            let resp = http_get(s, "/healthz");
            assert!(resp.starts_with("HTTP/1.1 200"), "round {round}: {resp}");
        }
    }

    // Thread budget: the connections must not have cost threads. The
    // bound is generous (sibling tests in this binary run their own
    // servers concurrently) but two orders below thread-per-connection.
    if let (Some(before), Some(during)) = (threads_before, process_thread_count()) {
        let grew = during.saturating_sub(before);
        assert!(
            grew < 200,
            "thread count grew by {grew} under {} connections \
             (thread-per-connection regression?)",
            RPC_CONNS + HTTP_CONNS
        );
    }

    let registry = server.registry();
    assert!(
        registry.counter("net.connections_accepted").get() >= (RPC_CONNS + HTTP_CONNS) as u64
    );
    assert!(
        registry.gauge("net.connections_active").get() >= (RPC_CONNS + HTTP_CONNS) as i64
    );
    // Ingress latency was measured for the dispatched requests.
    assert!(
        registry.histogram("net.read_to_dispatch_ns").count() >= (2 * RPC_CONNS) as u64
    );

    drop(rpc_clients);
    drop(http_conns);
    server.stop();
}

/// Slow-loris (half-sent request) and fully idle connections are both
/// closed by the idle sweep at `idle_timeout_ms` — no request ever
/// completes, so only the sweeper can reclaim them.
#[test]
fn slow_loris_and_idle_connections_are_swept() {
    let server = server_with(NetConfig {
        idle_timeout: Duration::from_millis(200),
        ..Default::default()
    });
    let rpc_addr = server.addr().to_string();
    let http_addr = server.http_addr().unwrap().to_string();

    // Half an HTTP request line, then silence.
    let mut loris_http = TcpStream::connect(&http_addr).unwrap();
    loris_http.write_all(b"GET /hea").unwrap();
    // A frame header claiming 100 bytes, with 2 bytes of payload.
    let mut loris_rpc = TcpStream::connect(&rpc_addr).unwrap();
    loris_rpc.write_all(&[100, 0, 0, 0, 7, 7]).unwrap();
    // A connection that never sends anything at all.
    let mut idle = TcpStream::connect(&rpc_addr).unwrap();

    let deadline = Instant::now() + Duration::from_secs(5);
    read_to_eof_by(&mut loris_http, deadline, "http slow-loris");
    read_to_eof_by(&mut loris_rpc, deadline, "rpc slow-loris");
    read_to_eof_by(&mut idle, deadline, "idle connection");
    assert!(
        server.registry().counter("net.idle_closed").get() >= 3,
        "sweeper closed fewer connections than it should have"
    );
    server.stop();
}

/// Connects above `max_connections` get an immediate, protocol-correct
/// reject — a framed `Unavailable` on the RPC plane, a 503 with
/// Retry-After on HTTP — and the gate holds on both planes at once
/// (the cap is shared reactor-wide).
#[test]
fn over_limit_connections_get_unavailable_and_503() {
    let server = server_with(NetConfig {
        max_connections: 4,
        ..Default::default()
    });
    let rpc_addr = server.addr().to_string();
    let http_addr = server.http_addr().unwrap().to_string();

    // Fill the cap with idle connections, half per plane, and wait for
    // the accepts to land (connect() returns before the server sees it).
    let _held: Vec<TcpStream> = (0..4)
        .map(|i| {
            TcpStream::connect(if i % 2 == 0 { &rpc_addr } else { &http_addr }).unwrap()
        })
        .collect();
    wait_active(&server, 4);

    // Over-limit RPC connect: the reject frame is pushed at accept.
    let mut over_rpc = TcpStream::connect(&rpc_addr).unwrap();
    let bytes = read_to_eof_by(&mut over_rpc, Instant::now() + Duration::from_secs(5), "rpc reject");
    assert!(bytes.len() > 4, "no reject frame, got {} bytes", bytes.len());
    let len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    let resp = Response::decode(&bytes[4..4 + len]).unwrap();
    match resp.into_result() {
        Err(e) => {
            assert_eq!(ErrorKind::of(&e), ErrorKind::Unavailable, "{e}");
            assert!(e.to_string().contains("connection limit"), "{e}");
        }
        Ok(other) => panic!("over-limit connect served normally: {other:?}"),
    }

    // Over-limit HTTP connect: 503 + Retry-After, then close.
    let mut over_http = TcpStream::connect(&http_addr).unwrap();
    let bytes =
        read_to_eof_by(&mut over_http, Instant::now() + Duration::from_secs(5), "http reject");
    let text = String::from_utf8_lossy(&bytes);
    assert!(text.starts_with("HTTP/1.1 503"), "{text}");
    assert!(text.contains("Retry-After"), "{text}");

    assert!(server.registry().counter("net.connections_rejected").get() >= 2);
    server.stop();
}

/// `stop()` is a drain, not an axe: a request already executing when
/// the stop begins still gets its reply flushed before the reactor
/// tears the connection down.
#[test]
fn stop_drains_in_flight_request() {
    let server = server_with(NetConfig::default());
    load_synthetic(&server, "drainmod");
    // Make the in-flight window wide enough to stop() into.
    tensorserve::util::fault::arm(
        "exec:drainmod",
        tensorserve::util::fault::Fault::Delay { duration: Duration::from_millis(300) },
        1,
    );

    let addr = server.addr().to_string();
    let worker = std::thread::spawn(move || {
        let mut client = RpcClient::connect(&addr).unwrap();
        client.call_ok(&Request::Predict {
            spec: ModelSpec::latest("drainmod"),
            signature: String::new(),
            inputs: vec![("x".into(), Tensor::matrix(vec![vec![0.5; 8]]).unwrap())],
        })
    });
    // Let the request reach the delayed device execution, then stop.
    std::thread::sleep(Duration::from_millis(100));
    server.stop();

    let resp = worker
        .join()
        .unwrap()
        .expect("in-flight request lost its reply to stop()");
    assert!(matches!(resp, Response::Predict { .. }));
}

/// The legacy threaded path behind `net.mode = "threaded"`: still
/// serves, and `stop()` returns promptly even with an idle connection
/// open — the connection threads are tracked and joined, not detached
/// and abandoned.
#[test]
fn threaded_mode_serves_and_stop_joins_connection_threads() {
    let server = server_with(NetConfig {
        mode: NetMode::Threaded,
        ..Default::default()
    });
    let rpc_addr = server.addr().to_string();
    let http_addr = server.http_addr().unwrap().to_string();

    let mut client = RpcClient::connect(&rpc_addr).unwrap();
    assert!(matches!(client.call_ok(&Request::Ping).unwrap(), Response::Pong));
    let mut http = TcpStream::connect(&http_addr).unwrap();
    let resp = http_get(&mut http, "/healthz");
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");

    // Idle connections on both planes would park their threads in a
    // blocking read for up to idle_timeout; stop() must not wait that
    // out (shutdown() unblocks them) and must join, not detach.
    let _idle_rpc = TcpStream::connect(&rpc_addr).unwrap();
    let _idle_http = TcpStream::connect(&http_addr).unwrap();
    std::thread::sleep(Duration::from_millis(50)); // let them be accepted
    let t0 = Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "threaded stop() hung on live connection threads: {:?}",
        t0.elapsed()
    );
}
