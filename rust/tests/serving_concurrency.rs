//! Cross-request batching on the live serving path, end to end:
//!
//! * N concurrent 1-row Predicts complete in ≪ N device executions
//!   (pinned via the synthetic servable's execution counter), through
//!   the real RPC server — proving requests from different connections
//!   merge into shared device batches.
//! * Concurrent MultiInference calls merge too (the ROADMAP "Batching
//!   for MultiInference" bullet's regression test).
//! * Unload-while-queued drains cleanly: queued requests get a
//!   retryable `FailedPrecondition` promptly — no hang, no
//!   use-after-unload, no device execution for drained work.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tensorserve::base::error::ErrorKind;
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::inference::multi::{multi_inference_with, InferenceTask, MultiInferenceRequest};
use tensorserve::inference::predict::{predict_with, PredictRequest};
use tensorserve::inference::ModelSpec;
use tensorserve::lifecycle::basic_manager::{BasicManager, VersionRequest};
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::runtime::hlo_servable::{synthetic_loader, HloServable};
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::ServerConfig;
use tensorserve::serving::{BatchingConfig, SessionRegistry};
use tensorserve::util::metrics::Registry;

fn example(i: usize) -> tensorserve::inference::example::Example {
    tensorserve::inference::example::Example::new().with(
        "x",
        tensorserve::inference::example::Feature::Floats(
            (0..8).map(|j| ((i * 8 + j) as f32) * 0.1).collect(),
        ),
    )
}

/// A manager with one synthetic multi-head servable and a registry
/// attached to its lifecycle.
fn stack(config: BatchingConfig) -> (Arc<BasicManager>, Arc<SessionRegistry>) {
    let manager = BasicManager::with_defaults();
    manager
        .load_and_wait(
            ServableId::new("syn", 1),
            synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", 1, 8, 3)),
            Duration::from_secs(30),
        )
        .unwrap();
    let registry = SessionRegistry::new(config, Registry::new());
    registry.attach(&manager);
    (manager, registry)
}

fn executions(manager: &Arc<BasicManager>) -> u64 {
    manager
        .handle::<HloServable>("syn", VersionRequest::Latest)
        .unwrap()
        .executions()
}

#[test]
fn concurrent_rpc_predicts_merge_into_shared_batches() {
    // The full serving stack: real RPC server, N client connections.
    let server = ModelServer::start(ServerConfig {
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        batching: BatchingConfig {
            batch_timeout: Duration::from_millis(10),
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    server
        .avm()
        .basic()
        .load_and_wait(
            ServableId::new("syn", 1),
            synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", 1, 8, 3)),
            Duration::from_secs(30),
        )
        .unwrap();
    let addr = server.addr().to_string();

    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 8;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = RpcClient::connect(&addr).unwrap();
                for i in 0..PER_CLIENT {
                    let row: Vec<f32> =
                        (0..8).map(|j| ((c * 37 + i * 8 + j) as f32) * 0.01).collect();
                    let resp = client
                        .call_ok(&Request::Predict {
                            spec: ModelSpec::latest("syn"),
                            signature: String::new(),
                            inputs: vec![("x".into(), Tensor::matrix(vec![row]).unwrap())],
                        })
                        .unwrap();
                    assert!(matches!(resp, Response::Predict { .. }));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let total = (CLIENTS * PER_CLIENT) as u64;
    let execs = server
        .avm()
        .handle::<HloServable>("syn", VersionRequest::Latest)
        .unwrap()
        .executions();
    assert!(
        execs < total,
        "{total} concurrent RPC predicts never merged: {execs} executions"
    );
    server.stop();
}

#[test]
fn concurrent_multi_inference_merges() {
    // Regression for the ROADMAP bullet: MultiInference's shared
    // execution routes through the per-model session, so concurrent
    // calls merge (executions < requests).
    let (manager, registry) = stack(BatchingConfig {
        batch_timeout: Duration::from_millis(20),
        ..Default::default()
    });
    const N: usize = 8;
    let handles: Vec<_> = (0..N)
        .map(|i| {
            let manager = Arc::clone(&manager);
            let registry = Arc::clone(&registry);
            std::thread::spawn(move || {
                multi_inference_with(
                    manager.as_ref(),
                    registry.as_ref(),
                    &MultiInferenceRequest {
                        spec: ModelSpec::latest("syn"),
                        tasks: vec![
                            InferenceTask::classify("classify"),
                            InferenceTask::regress("regress"),
                        ],
                        examples: vec![example(i)],
                    },
                )
                .unwrap()
            })
        })
        .collect();
    let mut responses = Vec::new();
    for h in handles {
        responses.push(h.join().unwrap());
    }
    let execs = executions(&manager);
    assert!(
        execs < N as u64,
        "{N} concurrent MultiInference calls never merged: {execs} executions"
    );
    // Merged results still match an unmerged run of the same example.
    let solo = multi_inference_with(
        manager.as_ref(),
        &tensorserve::serving::DirectRunner,
        &MultiInferenceRequest {
            spec: ModelSpec::latest("syn"),
            tasks: vec![
                InferenceTask::classify("classify"),
                InferenceTask::regress("regress"),
            ],
            examples: vec![example(3)],
        },
    )
    .unwrap();
    assert_eq!(responses[3].results, solo.results);
}

#[test]
fn unload_while_queued_drains_with_failed_precondition() {
    // A huge batch timeout + small load: requests sit queued in the
    // open batch. Unloading must answer them promptly with a
    // retryable FailedPrecondition — never a hang (the 30s timeout
    // here would trip) and never an execution against the unloaded
    // servable.
    let (manager, registry) = stack(BatchingConfig {
        max_batch_size: 64,
        batch_timeout: Duration::from_secs(30),
        num_batch_threads: 1,
        ..Default::default()
    });
    assert_eq!(registry.session_count(), 1);

    const N: usize = 6;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..N)
        .map(|i| {
            let manager = Arc::clone(&manager);
            let registry = Arc::clone(&registry);
            std::thread::spawn(move || {
                predict_with(
                    manager.as_ref(),
                    registry.as_ref(),
                    &PredictRequest {
                        spec: ModelSpec::latest("syn"),
                        signature: String::new(),
                        inputs: vec![(
                            "x".into(),
                            Tensor::matrix(vec![vec![i as f32; 8]]).unwrap(),
                        )],
                    },
                )
            })
        })
        .collect();
    // Wait until every request is actually sitting in the open batch,
    // then unload the version out from under them.
    let id = ServableId::new("syn", 1);
    let queued_deadline = Instant::now() + Duration::from_secs(10);
    while registry.pending_tasks(&id) < N {
        assert!(
            Instant::now() < queued_deadline,
            "only {} of {N} requests ever queued",
            registry.pending_tasks(&id)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    manager
        .unload_and_wait(id, Duration::from_secs(30))
        .unwrap();

    for h in handles {
        let err = h.join().unwrap().expect_err("queued request survived unload");
        assert_eq!(
            ErrorKind::of(&err),
            ErrorKind::FailedPrecondition,
            "drained request should be retryable: {err}"
        );
        let msg = err.to_string();
        assert!(msg.contains("unload") || msg.contains("retry") || msg.contains("closed"), "{msg}");
    }
    // Prompt: drained in far less than the 30s batch timeout.
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "drain waited out the batch timeout: {:?}",
        t0.elapsed()
    );
    assert_eq!(registry.session_count(), 0);
}
