//! Cross-request batching on the live serving path, end to end:
//!
//! * N concurrent 1-row Predicts complete in ≪ N device executions
//!   (pinned via the synthetic servable's execution counter), through
//!   the real RPC server — proving requests from different connections
//!   merge into shared device batches.
//! * Concurrent MultiInference calls merge too (the ROADMAP "Batching
//!   for MultiInference" bullet's regression test).
//! * Unload-while-queued drains cleanly: queued requests get a
//!   retryable `FailedPrecondition` promptly — no hang, no
//!   use-after-unload, no device execution for drained work.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use tensorserve::base::error::ErrorKind;
use tensorserve::batching::scheduler::{QueueOptions, SchedulerOptions, SharedBatchScheduler};
use tensorserve::batching::session::{BatchRunner, BatchingSession, SessionOptions};
use tensorserve::runtime::pjrt::OutTensor;
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::inference::multi::{multi_inference_with, InferenceTask, MultiInferenceRequest};
use tensorserve::inference::predict::{predict_with, PredictRequest};
use tensorserve::inference::ModelSpec;
use tensorserve::lifecycle::basic_manager::{BasicManager, VersionRequest};
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::runtime::hlo_servable::{synthetic_loader, HloServable};
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::ServerConfig;
use tensorserve::serving::{BatchingConfig, SessionRegistry};
use tensorserve::util::metrics::Registry;

fn example(i: usize) -> tensorserve::inference::example::Example {
    tensorserve::inference::example::Example::new().with(
        "x",
        tensorserve::inference::example::Feature::Floats(
            (0..8).map(|j| ((i * 8 + j) as f32) * 0.1).collect(),
        ),
    )
}

/// A manager with one synthetic multi-head servable and a registry
/// attached to its lifecycle.
fn stack(config: BatchingConfig) -> (Arc<BasicManager>, Arc<SessionRegistry>) {
    let manager = BasicManager::with_defaults();
    manager
        .load_and_wait(
            ServableId::new("syn", 1),
            synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", 1, 8, 3)),
            Duration::from_secs(30),
        )
        .unwrap();
    let registry = SessionRegistry::new(config, Registry::new());
    registry.attach(&manager);
    (manager, registry)
}

fn executions(manager: &Arc<BasicManager>) -> u64 {
    manager
        .handle::<HloServable>("syn", VersionRequest::Latest)
        .unwrap()
        .executions()
}

#[test]
fn concurrent_rpc_predicts_merge_into_shared_batches() {
    // The full serving stack: real RPC server, N client connections.
    let server = ModelServer::start(ServerConfig {
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        batching: BatchingConfig {
            batch_timeout: Duration::from_millis(10),
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    server
        .avm()
        .basic()
        .load_and_wait(
            ServableId::new("syn", 1),
            synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", 1, 8, 3)),
            Duration::from_secs(30),
        )
        .unwrap();
    let addr = server.addr().to_string();

    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 8;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = RpcClient::connect(&addr).unwrap();
                for i in 0..PER_CLIENT {
                    let row: Vec<f32> =
                        (0..8).map(|j| ((c * 37 + i * 8 + j) as f32) * 0.01).collect();
                    let resp = client
                        .call_ok(&Request::Predict {
                            spec: ModelSpec::latest("syn"),
                            signature: String::new(),
                            inputs: vec![("x".into(), Tensor::matrix(vec![row]).unwrap())],
                        })
                        .unwrap();
                    assert!(matches!(resp, Response::Predict { .. }));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let total = (CLIENTS * PER_CLIENT) as u64;
    let execs = server
        .avm()
        .handle::<HloServable>("syn", VersionRequest::Latest)
        .unwrap()
        .executions();
    assert!(
        execs < total,
        "{total} concurrent RPC predicts never merged: {execs} executions"
    );
    server.stop();
}

#[test]
fn concurrent_multi_inference_merges() {
    // Regression for the ROADMAP bullet: MultiInference's shared
    // execution routes through the per-model session, so concurrent
    // calls merge (executions < requests).
    let (manager, registry) = stack(BatchingConfig {
        batch_timeout: Duration::from_millis(20),
        ..Default::default()
    });
    const N: usize = 8;
    let handles: Vec<_> = (0..N)
        .map(|i| {
            let manager = Arc::clone(&manager);
            let registry = Arc::clone(&registry);
            std::thread::spawn(move || {
                multi_inference_with(
                    manager.as_ref(),
                    registry.as_ref(),
                    &MultiInferenceRequest {
                        spec: ModelSpec::latest("syn"),
                        tasks: vec![
                            InferenceTask::classify("classify"),
                            InferenceTask::regress("regress"),
                        ],
                        examples: vec![example(i)],
                    },
                )
                .unwrap()
            })
        })
        .collect();
    let mut responses = Vec::new();
    for h in handles {
        responses.push(h.join().unwrap());
    }
    let execs = executions(&manager);
    assert!(
        execs < N as u64,
        "{N} concurrent MultiInference calls never merged: {execs} executions"
    );
    // Merged results still match an unmerged run of the same example.
    let solo = multi_inference_with(
        manager.as_ref(),
        &tensorserve::serving::DirectRunner,
        &MultiInferenceRequest {
            spec: ModelSpec::latest("syn"),
            tasks: vec![
                InferenceTask::classify("classify"),
                InferenceTask::regress("regress"),
            ],
            examples: vec![example(3)],
        },
    )
    .unwrap();
    assert_eq!(responses[3].results, solo.results);
}

#[test]
fn unload_while_queued_drains_with_failed_precondition() {
    // A huge batch timeout + small load: requests sit queued in the
    // open batch. Unloading must answer them promptly with a
    // retryable FailedPrecondition — never a hang (the 30s timeout
    // here would trip) and never an execution against the unloaded
    // servable.
    let (manager, registry) = stack(BatchingConfig {
        max_batch_size: 64,
        batch_timeout: Duration::from_secs(30),
        num_batch_threads: 1,
        ..Default::default()
    });
    assert_eq!(registry.session_count(), 1);

    const N: usize = 6;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..N)
        .map(|i| {
            let manager = Arc::clone(&manager);
            let registry = Arc::clone(&registry);
            std::thread::spawn(move || {
                predict_with(
                    manager.as_ref(),
                    registry.as_ref(),
                    &PredictRequest {
                        spec: ModelSpec::latest("syn"),
                        signature: String::new(),
                        inputs: vec![(
                            "x".into(),
                            Tensor::matrix(vec![vec![i as f32; 8]]).unwrap(),
                        )],
                    },
                )
            })
        })
        .collect();
    // Wait until every request is actually sitting in the open batch,
    // then unload the version out from under them.
    let id = ServableId::new("syn", 1);
    let queued_deadline = Instant::now() + Duration::from_secs(10);
    while registry.pending_tasks(&id) < N {
        assert!(
            Instant::now() < queued_deadline,
            "only {} of {N} requests ever queued",
            registry.pending_tasks(&id)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    manager
        .unload_and_wait(id, Duration::from_secs(30))
        .unwrap();

    for h in handles {
        let err = h.join().unwrap().expect_err("queued request survived unload");
        assert_eq!(
            ErrorKind::of(&err),
            ErrorKind::FailedPrecondition,
            "drained request should be retryable: {err}"
        );
        let msg = err.to_string();
        assert!(msg.contains("unload") || msg.contains("retry") || msg.contains("closed"), "{msg}");
    }
    // Prompt: drained in far less than the 30s batch timeout.
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "drain waited out the batch timeout: {:?}",
        t0.elapsed()
    );
    assert_eq!(registry.session_count(), 0);
}

// ---------------------------------------------------- lane isolation
//
// The multi-tenant hazard: a slow model sharing the batch worker pool
// with a fast one. Lanes (weighted round-robin ready list) bound how
// far a fast model's work can queue behind a slow model's backlog, and
// `dedicated_threads` removes the coupling entirely.
//
// NOTE: benches/bench_tail_latency.rs (T2b) measures this same
// slow/fast scenario and commits the numbers to
// BENCH_tail_latency.json — keep the two harnesses' parameters
// (device time, pump count, lane options) in sync when tuning.

/// Device that sleeps per batch — a "slow model".
struct SleepRunner(Duration);

impl BatchRunner for SleepRunner {
    fn run_batch(&self, input: Tensor) -> anyhow::Result<Vec<OutTensor>> {
        std::thread::sleep(self.0);
        Ok(vec![OutTensor::F32(Tensor::new(
            input.shape().to_vec(),
            input.data().to_vec(),
        )?)])
    }
}

fn lane_session(
    sched: &SharedBatchScheduler<tensorserve::batching::session::PendingRun>,
    name: &str,
    device_time: Duration,
    dedicated_threads: usize,
) -> BatchingSession {
    BatchingSession::new(
        sched,
        name,
        SessionOptions {
            queue: QueueOptions {
                max_batch_size: 1, // every request closes a batch
                batch_timeout: Duration::from_micros(100),
                max_enqueued_batches: 1 << 20,
                dedicated_threads,
                ..Default::default()
            },
            allowed_batch_sizes: vec![1],
            ..Default::default()
        },
        Arc::new(SleepRunner(device_time)),
    )
}

/// p99 (ns) of `n` sequential 1-row requests against `session`.
fn fast_p99(session: &BatchingSession, n: usize) -> u64 {
    let hist = tensorserve::util::metrics::Histogram::new();
    for i in 0..n {
        let t0 = Instant::now();
        session
            .run(Tensor::matrix(vec![vec![i as f32]]).unwrap())
            .unwrap();
        hist.record_duration(t0.elapsed());
        std::thread::sleep(Duration::from_millis(1));
    }
    hist.quantile(0.99)
}

/// The acceptance gate: with a dedicated lane, the fast model's p99
/// while the slow lane is fully saturated stays within 3× its
/// uncontended p99 (floored at 5ms so scheduler-wakeup jitter can't
/// turn the ratio into noise).
#[test]
fn fast_lane_p99_bounded_while_slow_lane_saturated() {
    const SLOW_DEVICE: Duration = Duration::from_millis(50);
    let sched = Arc::new(SharedBatchScheduler::new(SchedulerOptions {
        num_batch_threads: 2,
        name: "iso".into(),
    }));
    let slow = Arc::new(lane_session(&sched, "slow", SLOW_DEVICE, 0));
    let fast = lane_session(&sched, "fast", Duration::ZERO, 1);

    // Uncontended baseline.
    let p99_uncontended = fast_p99(&fast, 30);

    // Saturate the slow lane: two pumps keep both shared workers
    // occupied with 50ms device calls continuously.
    let stop = Arc::new(AtomicBool::new(false));
    let pumps: Vec<_> = (0..2)
        .map(|_| {
            let slow = Arc::clone(&slow);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let _ = slow.run(Tensor::matrix(vec![vec![1.0]]).unwrap());
                }
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(60)); // pumps in flight

    // p99 of 30 samples ≈ the max sample, so a single long OS
    // deschedule (this binary's tests run in parallel) could trip the
    // gate without a real isolation defect: floor the baseline at 10ms
    // and allow one remeasure before declaring failure.
    let floor = Duration::from_millis(10).as_nanos() as u64;
    let bound = 3 * p99_uncontended.max(floor);
    let mut p99_saturated = 0;
    let mut isolated = false;
    for attempt in 0..2 {
        p99_saturated = fast_p99(&fast, 30);
        println!(
            "lane isolation (attempt {attempt}): fast p99 uncontended={}ns \
             saturated={}ns bound={}ns",
            p99_uncontended, p99_saturated, bound
        );
        if p99_saturated <= bound {
            isolated = true;
            break;
        }
    }
    stop.store(true, Ordering::Relaxed);
    for p in pumps {
        p.join().unwrap();
    }
    assert!(
        isolated,
        "fast-model p99 {}ns exceeded 3x its uncontended p99 {}ns (bound {}ns) \
         while the slow lane was saturated",
        p99_saturated,
        p99_uncontended,
        bound
    );
}

/// Even without dedicated threads, weighted round-robin lanes bound
/// head-of-line blocking: a fast request queued behind a 20-batch slow
/// backlog is served after at most ~one slow pick per worker, not
/// after the whole backlog drains.
#[test]
fn shared_lanes_round_robin_bounds_head_of_line_blocking() {
    const SLOW_DEVICE: Duration = Duration::from_millis(10);
    const BACKLOG: usize = 20;
    let sched = Arc::new(SharedBatchScheduler::new(SchedulerOptions {
        num_batch_threads: 1, // worst case: one worker for both lanes
        name: "rr".into(),
    }));
    let slow = Arc::new(lane_session(&sched, "slow", SLOW_DEVICE, 0));
    let fast = Arc::new(lane_session(&sched, "fast", Duration::ZERO, 0));

    // Pre-load the slow backlog (async senders so nothing blocks).
    let backlog: Vec<_> = (0..BACKLOG)
        .map(|_| {
            let slow = Arc::clone(&slow);
            std::thread::spawn(move || {
                let _ = slow.run(Tensor::matrix(vec![vec![1.0]]).unwrap());
            })
        })
        .collect();
    // Wait until the backlog is actually queued.
    let deadline = Instant::now() + Duration::from_secs(10);
    while slow.pending_tasks() < BACKLOG / 2 {
        assert!(Instant::now() < deadline, "backlog never queued");
        std::thread::sleep(Duration::from_millis(1));
    }

    let t0 = Instant::now();
    fast.run(Tensor::matrix(vec![vec![2.0]]).unwrap()).unwrap();
    let fast_latency = t0.elapsed();
    // Full drain costs BACKLOG × 10ms = 200ms; round-robin admits the
    // fast lane after at most a couple of slow batches (bound leaves
    // headroom for CI scheduling noise while staying well under the
    // 200ms full-drain signature of head-of-line blocking).
    assert!(
        fast_latency < Duration::from_millis(SLOW_DEVICE.as_millis() as u64 * 8),
        "fast request waited out the slow backlog: {fast_latency:?}"
    );
    for h in backlog {
        h.join().unwrap();
    }
}
