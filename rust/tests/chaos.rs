//! Chaos tests: graceful degradation under injected faults, end to
//! end through a real `ModelServer` (RPC + REST), no PJRT required.
//!
//! * A request whose deadline expires while queued behind a slow
//!   device batch is dropped **before** execution (pinned via the
//!   synthetic servable's execution counter) and answered
//!   `DEADLINE_EXCEEDED` / HTTP 504.
//! * Under saturation the admission layer sheds excess load with a
//!   retryable `UNAVAILABLE` / HTTP 503 + `Retry-After`, and recovers
//!   once the in-flight work drains.
//! * A transiently failing load retries with backoff at the AVM level:
//!   the previous version keeps serving throughout, the failure reason
//!   is visible in ModelStatus mid-flight, and the new version
//!   eventually comes up.
//!
//! The fault registry is process-global, so each test uses its own
//! model name (`ddl`, `shed`, `flaky`) and never calls `reset()`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};
use tensorserve::base::aspired::{AspiredVersionsCallback, ServableData};
use tensorserve::base::error::ErrorKind;
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::inference::ModelSpec;
use tensorserve::lifecycle::basic_manager::VersionRequest;
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::runtime::hlo_servable::{synthetic_loader, HloServable};
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::ServerConfig;
use tensorserve::serving::{AdmissionConfig, BatchingConfig};
use tensorserve::util::fault::{arm, charges, Fault};

fn predict_req(model: &str, seed: f32) -> Request {
    Request::Predict {
        spec: ModelSpec::latest(model),
        signature: String::new(),
        inputs: vec![("x".into(), Tensor::matrix(vec![vec![seed; 8]]).unwrap())],
    }
}

/// One raw HTTP/1.1 exchange (the test client can't set custom headers
/// or see response headers, and both matter here). `Connection: close`
/// lets us read to EOF. Returns `(head, body)`.
fn raw_http(
    addr: &str,
    method: &str,
    path: &str,
    extra_headers: &[(&str, &str)],
    body: &str,
) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n",
        body.len()
    );
    for (name, value) in extra_headers {
        req.push_str(&format!("{name}: {value}\r\n"));
    }
    req.push_str("\r\n");
    req.push_str(body);
    stream.write_all(req.as_bytes()).unwrap();
    let mut resp = Vec::new();
    stream.read_to_end(&mut resp).unwrap();
    let text = String::from_utf8_lossy(&resp).into_owned();
    let (head, body) = text
        .split_once("\r\n\r\n")
        .unwrap_or_else(|| panic!("malformed response: {text:?}"));
    (head.to_string(), body.to_string())
}

fn wait_until(timeout: Duration, what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn load_synthetic(server: &ModelServer, model: &str, version: u64) {
    server
        .avm()
        .basic()
        .load_and_wait(
            ServableId::new(model, version),
            synthetic_loader(ArtifactSpec::synthetic_multi_head(model, version, 8, 3)),
            Duration::from_secs(30),
        )
        .unwrap();
}

fn executions(server: &ModelServer, model: &str) -> u64 {
    server
        .avm()
        .handle::<HloServable>(model, VersionRequest::Latest)
        .unwrap()
        .executions()
}

/// A request that was viable at admission but expires while queued
/// behind a slow device batch is answered `DEADLINE_EXCEEDED` without
/// ever executing; an already-expired budget over REST is a 504.
#[test]
fn deadline_expired_in_queue_dropped_before_execution() {
    let server = ModelServer::start(ServerConfig {
        http_addr: Some("127.0.0.1:0".to_string()),
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        // One worker, one request per batch: a delayed execution
        // deterministically queues everything behind it.
        batching: BatchingConfig {
            max_batch_size: 1,
            batch_timeout: Duration::from_millis(1),
            num_batch_threads: 1,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    load_synthetic(&server, "ddl", 1);

    // Occupy the only worker: the next execution sleeps 600ms.
    arm("exec:ddl", Fault::Delay { duration: Duration::from_millis(600) }, 1);
    let addr = server.addr().to_string();
    let blocker = std::thread::spawn(move || {
        let mut c = RpcClient::connect(&addr).unwrap();
        c.call_ok(&predict_req("ddl", 1.0)) // no deadline: waits out the delay
    });
    // Let the blocker reach the device before the deadlined request
    // arrives (otherwise EDF would rightly serve the tighter deadline
    // first).
    std::thread::sleep(Duration::from_millis(150));

    let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
    let t0 = Instant::now();
    let err = client
        .call_ok(&predict_req("ddl", 2.0).with_deadline_ms(100))
        .expect_err("100ms budget behind a 600ms batch must expire");
    assert_eq!(ErrorKind::of(&err), ErrorKind::DeadlineExceeded, "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "expired request should be answered promptly, took {:?}",
        t0.elapsed()
    );
    assert!(matches!(blocker.join().unwrap().unwrap(), Response::Predict { .. }));
    // The blocker executed; the expired request never reached the
    // device.
    assert_eq!(executions(&server, "ddl"), 1);

    // REST: an already-spent budget is refused with 504 before any
    // device work.
    let (head, body) = raw_http(
        &server.http_addr().unwrap().to_string(),
        "POST",
        "/v1/models/ddl:predict",
        &[("X-Request-Deadline-Ms", "0")],
        &format!("{{\"instances\": [[{}]]}}", vec!["0.5"; 8].join(",")),
    );
    assert!(head.starts_with("HTTP/1.1 504 Gateway Timeout"), "{head}");
    assert!(body.contains("error"), "{body}");
    assert_eq!(executions(&server, "ddl"), 1);
    server.stop();
}

/// With the global in-flight cap saturated by slow executions, excess
/// load is shed — `UNAVAILABLE` over RPC, 503 + `Retry-After` over
/// REST — and service resumes once the in-flight work drains.
#[test]
fn saturation_sheds_load_with_retry_hint_then_recovers() {
    let server = ModelServer::start(ServerConfig {
        http_addr: Some("127.0.0.1:0".to_string()),
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        admission: AdmissionConfig {
            max_inflight: 2,
            max_inflight_per_model: 0,
            retry_after_ms: 1500,
        },
        ..Default::default()
    })
    .unwrap();
    load_synthetic(&server, "shed", 1);

    // Two admitted requests hold their permits across an 800ms device
    // delay, pinning the server at its cap.
    arm("exec:shed", Fault::Delay { duration: Duration::from_millis(800) }, 2);
    let pumps: Vec<_> = (0..2)
        .map(|i| {
            let addr = server.addr().to_string();
            std::thread::spawn(move || {
                let mut c = RpcClient::connect(&addr).unwrap();
                c.call_ok(&predict_req("shed", i as f32))
            })
        })
        .collect();
    wait_until(Duration::from_secs(5), "both permits taken", || {
        server.core().admission.inflight() == 2
    });

    // RPC probe: shed with a retryable kind naming the condition.
    let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
    let err = client
        .call_ok(&predict_req("shed", 9.0))
        .expect_err("request over the in-flight cap must be shed");
    assert_eq!(ErrorKind::of(&err), ErrorKind::Unavailable, "{err}");
    assert!(err.to_string().contains("overloaded"), "{err}");

    // REST probe: 503 with the configured Retry-After (1500ms rounds
    // up to 2s).
    let (head, body) = raw_http(
        &server.http_addr().unwrap().to_string(),
        "POST",
        "/v1/models/shed:predict",
        &[],
        &format!("{{\"instances\": [[{}]]}}", vec!["0.5"; 8].join(",")),
    );
    assert!(head.starts_with("HTTP/1.1 503 Service Unavailable"), "{head}");
    assert!(head.contains("Retry-After: 2"), "{head}");
    assert!(body.contains("error"), "{body}");

    // The saturating work itself was never harmed by the shedding.
    for p in pumps {
        assert!(matches!(p.join().unwrap().unwrap(), Response::Predict { .. }));
    }
    wait_until(Duration::from_secs(5), "permits released", || {
        server.core().admission.inflight() == 0
    });
    // Recovered: the same request that was just shed now serves.
    assert!(matches!(
        client.call_ok(&predict_req("shed", 9.0)).unwrap(),
        Response::Predict { .. }
    ));
    server.stop();
}

/// A load that fails transiently is retried with backoff by the AVM:
/// the failure reason is visible in ModelStatus while parked, the
/// previous version keeps serving the whole time, and the new version
/// comes up once the fault clears.
#[test]
fn transient_load_failure_retries_while_old_version_serves() {
    let server = ModelServer::start(ServerConfig {
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        load_retries: 3,
        load_retry_backoff: Duration::from_millis(300),
        ..Default::default()
    })
    .unwrap();
    let aspire = |versions: &[u64]| {
        let data = versions
            .iter()
            .map(|&v| {
                ServableData::ok(
                    ServableId::new("flaky", v),
                    synthetic_loader(ArtifactSpec::synthetic_multi_head("flaky", v, 8, 3)),
                )
            })
            .collect();
        server.avm().set_aspired_versions("flaky", data);
    };
    // v1 through the real aspired path (the server's own reconcile
    // ticker drives the load).
    aspire(&[1]);
    wait_until(Duration::from_secs(30), "v1 ready", || {
        server.avm().basic().ready_versions("flaky") == vec![1]
    });

    // v2's artifact read fails twice, then succeeds.
    arm("load:flaky", Fault::Fail { message: "transient artifact read".into() }, 2);
    aspire(&[1, 2]);

    // Mid-flight: v2 parks in Error with the reason readable off
    // ModelStatus; v1 answers traffic while it waits out the backoff.
    let status_of = |version: u64| -> Option<String> {
        match server.core().handle(Request::ModelStatus { model: "flaky".into() }) {
            Response::ModelStatus { versions } => versions
                .into_iter()
                .find(|(v, _)| *v == version)
                .map(|(_, state)| state),
            other => panic!("unexpected {other:?}"),
        }
    };
    wait_until(Duration::from_secs(15), "v2 parked in error state", || {
        status_of(2).is_some_and(|s| s.starts_with("error:") && s.contains("injected fault"))
    });
    let mut client = RpcClient::connect(&server.addr().to_string()).unwrap();
    assert!(matches!(
        client.call_ok(&predict_req("flaky", 1.0)).unwrap(),
        Response::Predict { .. }
    ));

    // Convergence: retries exhaust the armed charges and v2 comes up —
    // with v1 ready at every observation in between.
    wait_until(Duration::from_secs(30), "v2 ready after retries", || {
        let ready = server.avm().basic().ready_versions("flaky");
        assert!(ready.contains(&1), "v1 dropped out of serving: {ready:?}");
        ready.contains(&2)
    });
    assert_eq!(charges("load:flaky"), 0, "retries should have consumed the fault");
    assert!(matches!(
        client.call_ok(&predict_req("flaky", 2.0)).unwrap(),
        Response::Predict { .. }
    ));
    server.stop();
}
