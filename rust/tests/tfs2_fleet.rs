//! Fleet end-to-end: the assembled TFS² control plane against live
//! serving jobs, no precomputed artifacts needed (synthetic specs on
//! disk load through the ordinary FileSystemSource chain).
//!
//! * durable labels: canary/stable set before a controller restart
//!   resolve identically after, straight from the store;
//! * metric-driven autoscaling: real `batch.*.lane_depth` load adds a
//!   replica, drain removes it;
//! * hedged fleet routing: one fault-injected slow replica keeps
//!   routed p99 within 3x the no-fault p99.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
use tensorserve::base::tensor::Tensor;
use tensorserve::inference::ModelSpec;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::tfs2::autoscaler::AutoscalerConfig;
use tensorserve::tfs2::controller::Controller;
use tensorserve::tfs2::fleet::{Fleet, FleetConfig};
use tensorserve::tfs2::store::Store;
use tensorserve::util::fault::{arm, reset, Fault};

/// The fault registry is process-global and cluster jobs share names
/// ("job-0", ...) across tests, so fault-using tests run one at a
/// time.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn lock_faults() -> std::sync::MutexGuard<'static, ()> {
    FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Write synthetic multi-head specs under `root/model/{v}/spec.json`
/// so serving jobs load them through the normal filesystem chain.
/// Returns the RAM estimate for placement.
fn synthetic_artifacts(root: &Path, model: &str, versions: &[u64]) -> u64 {
    let mut ram = 0;
    for &v in versions {
        let spec = ArtifactSpec::synthetic_multi_head(model, v, 8, 3);
        ram = spec.ram_estimate_bytes;
        spec.write_to(&root.join(model).join(v.to_string())).unwrap();
    }
    ram
}

fn temp_root(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ts-fleet-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn reconcile_until_ready(fleet: &Fleet, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let report = fleet.reconcile().unwrap();
        if report.ready >= want {
            return;
        }
        assert!(Instant::now() < deadline, "fleet never ready: {report:?}");
        std::thread::sleep(Duration::from_millis(100));
    }
}

fn predict(spec: ModelSpec) -> Request {
    Request::Predict {
        spec,
        signature: String::new(),
        inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
    }
}

fn p99_ms(mut samples: Vec<f64>) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() as f64) * 0.99).ceil() as usize - 1;
    samples[idx]
}

#[test]
fn labels_survive_controller_restart_end_to_end() {
    let _guard = lock_faults();
    reset();
    let root = temp_root("labels");
    let ram = synthetic_artifacts(&root, "label_m", &[1, 2]);
    let store_path = root.join("control-store");

    let fleet = Fleet::start(
        Store::open(&store_path, 0).unwrap(),
        FleetConfig { jobs: 1, artifacts_root: root.clone(), ..Default::default() },
    )
    .unwrap();
    fleet.deploy("label_m", root.to_str().unwrap(), ram, 1).unwrap();
    fleet.controller.set_canary("label_m", true).unwrap();
    fleet.controller.add_version("label_m", 2).unwrap();
    reconcile_until_ready(&fleet, 1);

    // Durable labels, fanned out to the replicas on the same pass.
    fleet.set_label("label_m", "stable", 1).unwrap();
    fleet.set_label("label_m", "canary", 2).unwrap();

    // The data plane resolves them end to end through the router.
    for (label, want) in [("stable", 1u64), ("canary", 2)] {
        match fleet
            .router
            .route(&predict(ModelSpec::with_label("label_m", label)))
            .unwrap()
        {
            Response::Predict { model_version, .. } => {
                assert_eq!(model_version, want, "label {label}")
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    let before = (
        fleet.controller.resolve_label("label_m", "stable").unwrap(),
        fleet.controller.resolve_label("label_m", "canary").unwrap(),
    );
    fleet.stop();
    drop(fleet);

    // Controller restart: a fresh instance over the same on-disk
    // store must resolve both labels identically, with no RPC fanout
    // or operator involvement.
    let controller = Controller::new(Store::open(&store_path, 0).unwrap());
    let after = (
        controller.resolve_label("label_m", "stable").unwrap(),
        controller.resolve_label("label_m", "canary").unwrap(),
    );
    assert_eq!(before, after);
    assert_eq!(after, (1, 2));
    let mut labels = controller.version_labels("label_m");
    labels.sort();
    assert_eq!(labels, vec![("canary".to_string(), 2), ("stable".to_string(), 1)]);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn autoscaler_scales_on_real_lane_depth_and_drains_back() {
    let _guard = lock_faults();
    reset();
    let root = temp_root("autoscale");
    let ram = synthetic_artifacts(&root, "autoscale_m", &[1]);

    let fleet = Arc::new(
        Fleet::start(
            Store::in_memory(0),
            FleetConfig {
                jobs: 1,
                artifacts_root: root.clone(),
                autoscaler: AutoscalerConfig {
                    target_load_per_replica: 2.0,
                    up_threshold: 1.2,
                    down_threshold: 0.5,
                    min_replicas: 1,
                    max_replicas: 3,
                    cooldown_ticks: 1,
                    // The SLO trigger now reads the *windowed*
                    // queue-delay p99, which empties once load stops —
                    // so the default threshold no longer pins
                    // scale-ups after the drain and needs no opt-out.
                    queue_delay_slo_ns: 5e7,
                    shed_weight: 1.0,
                },
                ..Default::default()
            },
        )
        .unwrap(),
    );
    fleet.deploy("autoscale_m", root.to_str().unwrap(), ram, 1).unwrap();
    reconcile_until_ready(&fleet, 1);

    // Slow every execution so concurrent traffic piles up in the
    // batching lanes — real queued work, not a synthetic load number.
    arm(
        "exec:autoscale_m",
        Fault::Delay { duration: Duration::from_millis(5) },
        1_000_000,
    );
    let stop = Arc::new(AtomicBool::new(false));
    let loaders: Vec<_> = (0..8)
        .map(|_| {
            let fleet = Arc::clone(&fleet);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let _ = fleet
                        .router
                        .route(&predict(ModelSpec::latest("autoscale_m")));
                }
            })
        })
        .collect();

    // Scrape → decide → scale loop until the fleet grows.
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut scaled_up = false;
    while Instant::now() < deadline {
        let decisions = fleet.autoscale_once().unwrap();
        if decisions.iter().any(|d| d.to > d.from) {
            scaled_up = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(scaled_up, "lane-depth load never triggered a scale-up");
    assert!(fleet.cluster.replica_addrs("job-0").len() >= 2);

    // Drain: stop the load, disarm the fault, and the same signals
    // walk the job back down to one replica.
    stop.store(true, Ordering::Relaxed);
    for h in loaders {
        h.join().unwrap();
    }
    arm("exec:autoscale_m", Fault::Delay { duration: Duration::ZERO }, 0);
    let deadline = Instant::now() + Duration::from_secs(60);
    while fleet.cluster.replica_addrs("job-0").len() > 1 {
        fleet.autoscale_once().unwrap();
        assert!(Instant::now() < deadline, "fleet never scaled back down");
        std::thread::sleep(Duration::from_millis(100));
    }
    assert_eq!(fleet.cluster.replica_addrs("job-0").len(), 1);
    reset();
    fleet.stop();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn hedged_routing_keeps_p99_within_3x_despite_slow_replica() {
    let _guard = lock_faults();
    reset();
    let root = temp_root("hedge");
    let ram = synthetic_artifacts(&root, "hedge_m", &[1]);

    let fleet = Fleet::start(
        Store::in_memory(0),
        FleetConfig {
            jobs: 1,
            artifacts_root: root.clone(),
            // Hedge fires after one nominal service time, so a routed
            // request stuck on the slow replica pays ~2x, never ~20x.
            hedge_delay: Duration::from_millis(20),
            ..Default::default()
        },
    )
    .unwrap();
    fleet.deploy("hedge_m", root.to_str().unwrap(), ram, 1).unwrap();
    fleet.cluster.scale_to("job-0", 2).unwrap();
    reconcile_until_ready(&fleet, 2); // model ready on both replicas

    // Nominal service time ~20ms on every replica.
    arm(
        "exec:hedge_m",
        Fault::Delay { duration: Duration::from_millis(20) },
        1_000_000,
    );
    let route_ms = |n: usize| -> Vec<f64> {
        (0..n)
            .map(|_| {
                let t0 = Instant::now();
                match fleet
                    .router
                    .route(&predict(ModelSpec::latest("hedge_m")))
                    .unwrap()
                {
                    Response::Predict { .. } => {}
                    other => panic!("unexpected {other:?}"),
                }
                t0.elapsed().as_secs_f64() * 1e3
            })
            .collect()
    };

    // Warm the connections, then measure the no-fault baseline.
    route_ms(5);
    let baseline = p99_ms(route_ms(30));

    // One replica turns slow: every RPC it handles stalls 400ms. The
    // round-robin router keeps picking it as primary half the time;
    // hedging must mask it.
    arm(
        "rpc:job-0/1",
        Fault::Delay { duration: Duration::from_millis(400) },
        10_000,
    );
    let hedged = p99_ms(route_ms(60));
    assert!(
        hedged <= baseline * 3.0,
        "hedged p99 {hedged:.1}ms > 3x no-fault p99 {baseline:.1}ms"
    );
    assert!(fleet.router.hedge_rate() > 0.0, "no hedges fired");
    reset();
    fleet.stop();
    let _ = std::fs::remove_dir_all(&root);
}
