//! Differential fuzz harness for the wire codecs.
//!
//! A seeded [`tensorserve::util::rng::Rng`] generates valid and
//! adversarial predict bodies; every one is decoded by both the
//! SIMD/SWAR fast-path codec and the scalar JSON codec, and the two
//! must agree exactly — bit-identical tensors on success, the same
//! error text on failure. A non-vacuity check pins that canonical
//! float-array bodies really take the fast path rather than falling
//! back wholesale. Runs as a named step in `scripts/check.sh`
//! (`cargo test -q --test codec_fuzz`).

use tensorserve::http::codec::{parse_predict_body, PredictBody};
use tensorserve::http::wire::{self, simd::FastResult, Codec};
use tensorserve::util::rng::Rng;

/// Append one random float in a random JSON spelling.
fn push_number(rng: &mut Rng, out: &mut String) {
    match rng.next_below(6) {
        0 => out.push_str(&format!("{}", rng.next_below(1000) as i64 - 500)),
        1 => out.push_str(&format!("{:.3}", rng.next_f64() * 200.0 - 100.0)),
        2 => out.push_str(&format!("{:e}", rng.next_f64() * 1e6)),
        3 => out.push_str(&format!("{}", rng.next_f64())),
        4 => out.push_str(&format!(
            "{}e{}",
            rng.next_below(100),
            rng.next_below(40) as i64 - 20
        )),
        _ => out.push_str(&format!(
            "-{}.{}E+{}",
            rng.next_below(10),
            rng.next_below(1000),
            rng.next_below(3)
        )),
    }
}

/// A well-formed row-format body the fast path should handle: optional
/// signature, scalar or array rows, mixed number spellings, stray
/// whitespace.
fn gen_valid_body(rng: &mut Rng) -> String {
    let mut s = String::from("{");
    if rng.chance(0.3) {
        s.push_str("\"signature_name\": \"serving_default\", ");
    }
    s.push_str("\"instances\": [");
    let rows = rng.range(1, 5);
    let width = rng.range(1, 9);
    let scalar_rows = rng.chance(0.25);
    for r in 0..rows {
        if r > 0 {
            s.push(',');
            if rng.chance(0.3) {
                s.push(' ');
            }
        }
        if scalar_rows {
            push_number(rng, &mut s);
        } else {
            s.push('[');
            for c in 0..width {
                if c > 0 {
                    s.push(',');
                }
                push_number(rng, &mut s);
            }
            s.push(']');
        }
    }
    s.push_str("]}");
    s
}

/// A well-formed body off the hot grammar: column format, feature-map
/// instances, ragged rows, nulls — all scalar-codec territory.
fn gen_cold_body(rng: &mut Rng) -> String {
    match rng.next_below(4) {
        0 => format!(
            "{{\"inputs\": {{\"x\": [[1,2],[3,{}]]}}}}",
            rng.next_below(50)
        ),
        1 => format!("{{\"instances\": [{{\"x\": [{}]}}]}}", rng.next_below(9)),
        2 => "{\"instances\": [[1,2],[3]]}".to_string(),
        _ => "{\"signature_name\": \"s\", \"instances\": [[1,null]]}".to_string(),
    }
}

/// One random byte-level mutation: truncate, flip, insert, or delete.
fn mutate(rng: &mut Rng, base: &str) -> Vec<u8> {
    let mut b = base.as_bytes().to_vec();
    match rng.next_below(4) {
        0 => {
            let cut = rng.range(0, b.len() + 1);
            b.truncate(cut);
        }
        1 => {
            let i = rng.range(0, b.len());
            b[i] = rng.next_below(256) as u8;
        }
        2 => {
            let i = rng.range(0, b.len() + 1);
            b.insert(i, rng.next_below(256) as u8);
        }
        _ => {
            let i = rng.range(0, b.len());
            b.remove(i);
        }
    }
    b
}

fn assert_same(a: &PredictBody, b: &PredictBody, body: &[u8]) {
    let ctx = String::from_utf8_lossy(body);
    assert_eq!(a.signature, b.signature, "{ctx}");
    assert_eq!(a.row_format, b.row_format, "{ctx}");
    assert_eq!(a.inputs.len(), b.inputs.len(), "{ctx}");
    for ((an, at), (bn, bt)) in a.inputs.iter().zip(&b.inputs) {
        assert_eq!(an, bn, "{ctx}");
        assert_eq!(at.shape(), bt.shape(), "{ctx}");
        let abits: Vec<u32> = at.data().iter().map(|v| v.to_bits()).collect();
        let bbits: Vec<u32> = bt.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(abits, bbits, "{ctx}");
    }
}

/// The differential oracle: SIMD codec and scalar codec must agree on
/// every body, success or failure.
fn assert_agree(body: &[u8]) {
    let fast = wire::simd_json().decode_predict(body);
    let slow = wire::scalar_json().decode_predict(body);
    match (fast, slow) {
        (Ok(a), Ok(b)) => assert_same(&a, &b, body),
        (Err(a), Err(b)) => assert_eq!(
            a.to_string(),
            b.to_string(),
            "{}",
            String::from_utf8_lossy(body)
        ),
        (a, b) => panic!(
            "codec divergence on {:?}: simd ok={} scalar ok={}",
            String::from_utf8_lossy(body),
            a.is_ok(),
            b.is_ok()
        ),
    }
}

#[test]
fn valid_bodies_agree_and_mostly_take_the_fast_path() {
    let mut rng = Rng::new(0x5EED_C0DE);
    let mut hot = 0usize;
    const N: usize = 400;
    for _ in 0..N {
        let body = gen_valid_body(&mut rng);
        if matches!(
            wire::simd::parse_predict_fast(body.as_bytes()),
            FastResult::Parsed(_)
        ) {
            hot += 1;
        }
        assert_agree(body.as_bytes());
    }
    // Non-vacuity: the generator's canonical bodies must actually
    // exercise the fast path, not fall back wholesale.
    assert!(hot >= N / 2, "only {hot}/{N} bodies took the fast path");
}

#[test]
fn cold_and_mutated_bodies_agree() {
    let mut rng = Rng::new(0xAD5E_ED42);
    for i in 0..300 {
        let base = if i % 3 == 0 {
            gen_cold_body(&mut rng)
        } else {
            gen_valid_body(&mut rng)
        };
        assert_agree(base.as_bytes());
        assert_agree(&mutate(&mut rng, &base));
    }
}

#[test]
fn adversarial_corpus_agrees() {
    let corpus: &[&[u8]] = &[
        b"",
        b"{",
        b"null",
        b"{\"instances\": []}",
        b"{\"instances\": [[]]}",
        b"{\"instances\": [[1e309]]}",
        b"{\"instances\": [[-0.0, 1e-320, 5e-324]]}",
        b"{\"instances\": [[1.7976931348623157e308]]}",
        b"{\"instances\": [[12345678901234567890123456789]]}",
        b"{\"instances\": [[01]]}",
        b"{\"instances\": [[1.]]}",
        b"{\"instances\": [[.5]]}",
        b"{\"instances\": [[+1]]}",
        "{\"instances\": [[1\u{2603}]]}".as_bytes(),
        b"{\"instances\": [[1]]}x",
        b"{\"instances\": [[1]], \"instances\": [[2]]}",
        b"{\"signature_name\": \"a\", \"signature_name\": \"b\", \"instances\": [[1]]}",
        b"{\"signature_name\": \"a\\u0041\", \"instances\": [[1]]}",
        b"{\"signature_name\": 7, \"instances\": [[1]]}",
        b"{\"instances\": [[[[[[[[[[1]]]]]]]]]]}",
        b"{\"instances\": [[1,2],[3,4],[5]]}",
        b"{\"instances\": [1, [2]]}",
        b"{\"instances\": [[1], 2]}",
        &[0xff, 0xfe, 0x00, 0x01],
        b"  {\"instances\": [[1]]}  ",
        b"{\"instances\":[[1]],\"unknown_key\":true}",
    ];
    for body in corpus {
        assert_agree(body);
    }
}

#[test]
fn chunked_feeds_match_one_shot_parse() {
    let mut rng = Rng::new(0xC47_FEED);
    for i in 0..120 {
        let body = if i % 4 == 0 {
            gen_cold_body(&mut rng)
        } else {
            gen_valid_body(&mut rng)
        };
        let bytes = body.as_bytes();
        let whole = wire::simd_json().decode_predict(bytes);
        let mut p = wire::simd::FastPredictParser::new();
        let mut off = 0;
        while off < bytes.len() {
            let take = rng.range(1, 9).min(bytes.len() - off);
            p.feed(&bytes[off..off + take]);
            off += take;
        }
        let streamed = match p.finish() {
            FastResult::Parsed(parsed) => Ok(parsed),
            FastResult::Fallback(raw) => {
                // A bail must hand the scalar codec the exact bytes.
                assert_eq!(raw, bytes, "{body}");
                parse_predict_body(&raw)
            }
        };
        match (whole, streamed) {
            (Ok(a), Ok(b)) => assert_same(&a, &b, bytes),
            (Err(a), Err(b)) => assert_eq!(a.to_string(), b.to_string(), "{body}"),
            (a, b) => panic!(
                "chunked/one-shot divergence on {body:?}: whole ok={} streamed ok={}",
                a.is_ok(),
                b.is_ok()
            ),
        }
    }
}
