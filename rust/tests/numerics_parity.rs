//! Cross-layer numerics parity: the AOT-compiled HLO executed from rust
//! via PJRT must reproduce jax's own predictions bit-for-bit (within f32
//! tolerance) on golden inputs written by `python/compile/aot.py`.
//!
//! This gate exists because HLO-text interchange has a silent failure
//! mode: default printing elides large constants as `{...}`, which the
//! parser reparses as zeros — models then "work" (valid shapes, valid
//! distributions) while computing garbage. Structural tests cannot catch
//! that; golden values do.

use tensorserve::base::tensor::Tensor;
use tensorserve::runtime::artifacts::{artifacts_available, default_artifacts_root};
use tensorserve::runtime::hlo_servable::HloServable;
use tensorserve::runtime::pjrt::{OutTensor, XlaRuntime};
use tensorserve::util::json::Json;

fn check_version(model: &str, version: u64) {
    let dir = default_artifacts_root().join(model).join(version.to_string());
    let golden = Json::parse_file(&dir.join("golden.json")).unwrap();
    let inputs: Vec<Vec<f32>> = golden
        .get("inputs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| {
            row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect()
        })
        .collect();

    let rt = XlaRuntime::shared().unwrap();
    let servable = HloServable::load(&rt, &dir).unwrap();
    let got = servable.run(&Tensor::matrix(inputs).unwrap()).unwrap();

    let want = golden.get("outputs").unwrap().as_arr().unwrap();
    assert_eq!(got.len(), want.len(), "{model}:{version} output arity");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let values: Vec<f64> = w
            .get("values")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        match g {
            OutTensor::F32(t) => {
                assert_eq!(t.data().len(), values.len());
                for (j, (a, b)) in t.data().iter().zip(&values).enumerate() {
                    assert!(
                        (*a as f64 - b).abs() < 1e-4,
                        "{model}:{version} output {i}[{j}]: rust {a} vs jax {b}"
                    );
                }
            }
            OutTensor::I32(t) => {
                assert_eq!(t.data().len(), values.len());
                for (j, (a, b)) in t.data().iter().zip(&values).enumerate() {
                    assert_eq!(
                        *a as f64, *b,
                        "{model}:{version} output {i}[{j}]: rust {a} vs jax {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn classifier_versions_match_jax() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    check_version("mlp_classifier", 1);
    check_version("mlp_classifier", 2);
}

#[test]
fn regressor_versions_match_jax() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    check_version("mlp_regressor", 1);
    check_version("mlp_regressor", 2);
}
