//! End-to-end benchmark: the real AOT-compiled classifier served over
//! the full stack (RPC → manager → PJRT executable), thread sweep.
//! Complements T1 (which factors the model and RPC layers out) by
//! showing where the time goes when they are factored back in — the
//! paper's own observation: "the main bottlenecks lie in the RPC and
//! TensorFlow layers".

use std::time::Duration;
use tensorserve::base::tensor::Tensor;
use tensorserve::inference::predict::{predict, PredictRequest};
use tensorserve::lifecycle::source::ServingPolicy;
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::{artifacts_available, default_artifacts_root};
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::{ModelConfig, ServerConfig};
use tensorserve::sim::workload::closed_loop;
use tensorserve::util::bench::{fmt_count, Table};
use tensorserve::util::metrics::fmt_nanos;

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    if !artifacts_available() {
        eprintln!("bench_e2e: artifacts missing — run `make artifacts`");
        return;
    }
    let server = ModelServer::start(ServerConfig {
        models: vec![ModelConfig {
            name: "mlp_classifier".into(),
            platform: "hlo".into(),
            base_path: default_artifacts_root().join("mlp_classifier"),
            policy: ServingPolicy::Latest(1),
        }],
        poll_interval: Some(Duration::from_millis(200)),
        ..Default::default()
    })
    .unwrap();
    server.wait_until_ready(Duration::from_secs(300)).unwrap();
    let addr = server.addr().to_string();
    let dur = tensorserve::util::bench::bench_duration(Duration::from_secs(3));

    // --- full stack over RPC ------------------------------------------
    let mut t = Table::new(
        "E2E: predict(b=1) through RPC + manager + PJRT (real model)",
        &["threads", "qps", "p50", "p99"],
    );
    for threads in [1usize, 4, 8, 16] {
        let addr = addr.clone();
        let stats = closed_loop(threads, dur, move |_| {
            thread_local! {
                static CLIENT: std::cell::RefCell<Option<RpcClient>> =
                    const { std::cell::RefCell::new(None) };
            }
            CLIENT.with(|c| {
                let mut c = c.borrow_mut();
                if c.is_none() {
                    *c = Some(RpcClient::connect(&addr)?);
                }
                let resp = c.as_mut().unwrap().call_ok(&Request::predict(
                    "mlp_classifier",
                    None,
                    Tensor::zeros(vec![1, 32]),
                ))?;
                anyhow::ensure!(matches!(resp, Response::Predict { .. }));
                Ok(())
            })
        });
        let (p50, _, p99, _) = stats.latency.percentiles();
        t.row(vec![
            threads.to_string(),
            fmt_count(stats.qps()),
            fmt_nanos(p50),
            fmt_nanos(p99),
        ]);
    }
    t.print();

    // --- layer decomposition at 8 threads ------------------------------
    let mut t = Table::new(
        "E2E-b: where the time goes (8 threads) — paper: 'bottlenecks lie in the RPC and TensorFlow layers'",
        &["path", "qps", "p50"],
    );
    // (1) RPC floor: ping only.
    {
        let addr = addr.clone();
        let stats = closed_loop(8, dur, move |_| {
            thread_local! {
                static CLIENT: std::cell::RefCell<Option<RpcClient>> =
                    const { std::cell::RefCell::new(None) };
            }
            CLIENT.with(|c| {
                let mut c = c.borrow_mut();
                if c.is_none() {
                    *c = Some(RpcClient::connect(&addr)?);
                }
                c.as_mut().unwrap().call_ok(&Request::Ping)?;
                Ok(())
            })
        });
        let (p50, _, _, _) = stats.latency.percentiles();
        t.row(vec!["RPC only (ping)".into(), fmt_count(stats.qps()), fmt_nanos(p50)]);
    }
    // (2) framework + model, no RPC (in-process predict).
    {
        let avm = std::sync::Arc::clone(server.avm());
        let stats = closed_loop(8, dur, move |_| {
            predict(
                avm.as_ref(),
                &PredictRequest::single("mlp_classifier", None, Tensor::zeros(vec![1, 32])),
            )?;
            Ok(())
        });
        let (p50, _, _, _) = stats.latency.percentiles();
        t.row(vec![
            "manager+model (no RPC)".into(),
            fmt_count(stats.qps()),
            fmt_nanos(p50),
        ]);
    }
    // (3) full stack (from the sweep above, rerun for the same config).
    {
        let addr = addr.clone();
        let stats = closed_loop(8, dur, move |_| {
            thread_local! {
                static CLIENT: std::cell::RefCell<Option<RpcClient>> =
                    const { std::cell::RefCell::new(None) };
            }
            CLIENT.with(|c| {
                let mut c = c.borrow_mut();
                if c.is_none() {
                    *c = Some(RpcClient::connect(&addr)?);
                }
                c.as_mut().unwrap().call_ok(&Request::predict(
                    "mlp_classifier",
                    None,
                    Tensor::zeros(vec![1, 32]),
                ))?;
                Ok(())
            })
        });
        let (p50, _, _, _) = stats.latency.percentiles();
        t.row(vec!["full stack".into(), fmt_count(stats.qps()), fmt_nanos(p50)]);
    }
    t.print();
    server.stop();
}
