//! Experiment T1 — the §4 headline: "TensorFlow-Serving itself can
//! handle about 100,000 requests per second per core" with the RPC and
//! model layers factored out (their testbed: 16 vCPU Xeon E5 2.6 GHz).
//!
//! We serve [`NullServable`]s: the full framework path runs — RCU
//! serving-map lookup, version resolution, typed handle checkout with
//! deferred-drop refcounting, dispatch, metrics — but "inference" is a
//! counter bump and the RPC layer is absent, exactly the paper's
//! methodology. Rows report qps and qps/core across a thread sweep, and
//! scaling with the number of resident models.

use std::sync::Arc;
use std::time::Duration;
use tensorserve::base::servable::ServableId;
use tensorserve::inference::null::{null_loader, NullServable};
use tensorserve::lifecycle::basic_manager::{BasicManager, VersionRequest};
use tensorserve::sim::workload::closed_loop;
use tensorserve::util::bench::{fmt_count, Table};
use tensorserve::util::json::Json;

fn manager_with_models(n: usize) -> Arc<BasicManager> {
    let m = BasicManager::with_defaults();
    for i in 0..n {
        m.load_and_wait(
            ServableId::new(format!("model-{i}"), 1),
            null_loader(),
            Duration::from_secs(10),
        )
        .unwrap();
    }
    m
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let dur = tensorserve::util::bench::bench_duration(Duration::from_secs(2));
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("testbed: {cores} core(s) (paper testbed: 16 vCPU Xeon E5 2.6GHz)");

    // ---- thread sweep, 1 model -------------------------------------
    let mut t = Table::new(
        "T1: framework-only throughput (null servable, no RPC) — paper: ~100k qps/core",
        &["threads", "qps", "qps/core", "p50", "p99.9"],
    );
    let mut sweep_json = Vec::new();
    for threads in [1usize, 2, 4, 8, 16] {
        let m = manager_with_models(1);
        let stats = closed_loop(threads, dur, move |_| {
            let h = m.handle::<NullServable>("model-0", VersionRequest::Latest)?;
            h.run(1);
            Ok(())
        });
        let (p50, _, _, p999) = stats.latency.percentiles();
        // Threads beyond physical cores time-slice: divide by the
        // smaller of the two for an honest per-core figure.
        let eff_cores = threads.min(cores) as f64;
        sweep_json.push(Json::obj(vec![
            ("threads", Json::num(threads as f64)),
            ("qps", Json::num(stats.qps())),
            ("qps_per_core", Json::num(stats.qps() / eff_cores)),
            ("ns_per_request_mean", Json::num(stats.latency.mean())),
            ("p50_ns", Json::num(p50 as f64)),
            ("p999_ns", Json::num(p999 as f64)),
        ]));
        t.row(vec![
            threads.to_string(),
            fmt_count(stats.qps()),
            fmt_count(stats.qps() / eff_cores),
            tensorserve::util::metrics::fmt_nanos(p50),
            tensorserve::util::metrics::fmt_nanos(p999),
        ]);
    }
    t.print();

    // ---- model-count sweep, 8 threads -------------------------------
    let mut t = Table::new(
        "T1b: lookup scaling with resident model count (8 threads)",
        &["models", "qps", "qps/core"],
    );
    let eff = 8.0f64.min(cores as f64);
    let mut models_json = Vec::new();
    for models in [1usize, 10, 100, 1000] {
        let m = manager_with_models(models);
        let stats = closed_loop(8, dur, move |tid| {
            let name = format!("model-{}", tid % models);
            let h = m.handle::<NullServable>(&name, VersionRequest::Latest)?;
            h.run(1);
            Ok(())
        });
        models_json.push(Json::obj(vec![
            ("models", Json::num(models as f64)),
            ("qps", Json::num(stats.qps())),
            ("qps_per_core", Json::num(stats.qps() / eff)),
            ("ns_per_request_mean", Json::num(stats.latency.mean())),
        ]));
        t.row(vec![
            models.to_string(),
            fmt_count(stats.qps()),
            fmt_count(stats.qps() / eff),
        ]);
    }
    t.print();

    // ---- specific-version vs latest ---------------------------------
    let mut t = Table::new(
        "T1c: version resolution cost (8 threads, 1 model)",
        &["lookup", "qps/core"],
    );
    for (label, specific) in [("latest", false), ("specific", true)] {
        let m = manager_with_models(1);
        let stats = closed_loop(8, dur, move |_| {
            let req = if specific {
                VersionRequest::Specific(1)
            } else {
                VersionRequest::Latest
            };
            let h = m.handle::<NullServable>("model-0", req)?;
            h.run(1);
            Ok(())
        });
        t.row(vec![label.to_string(), fmt_count(stats.qps() / eff)]);
    }
    t.print();

    // ---- request codec cost (API overhead tracking) ------------------
    // The signature-addressed wire format adds structure to every
    // Predict frame; decode ns/op is tracked here so API redesigns
    // show up in the trajectory.
    let mut t = Table::new(
        "T1d: request codec cost (Predict b=4, 32 features, named input)",
        &["op", "ns/op", "bytes"],
    );
    let mut codec_json = Vec::new();
    {
        use tensorserve::base::tensor::Tensor;
        use tensorserve::rpc::proto::Request;
        let req = Request::predict("model-0", None, Tensor::zeros(vec![4, 32]));
        let encoded = req.encode();
        let iters = 100_000u32;
        let t0 = std::time::Instant::now();
        let mut buf = Vec::new();
        for _ in 0..iters {
            req.encode_framed_into(&mut buf);
            std::hint::black_box(&buf);
        }
        let encode_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(Request::decode(&encoded).unwrap());
        }
        let decode_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        for (op, ns) in [("encode_framed", encode_ns), ("decode", decode_ns)] {
            t.row(vec![
                op.to_string(),
                format!("{ns:.0}"),
                encoded.len().to_string(),
            ]);
            codec_json.push(Json::obj(vec![
                ("op", Json::str(op)),
                ("ns_per_op", Json::num(ns)),
                ("frame_bytes", Json::num(encoded.len() as f64)),
            ]));
        }
    }
    t.print();

    // ---- machine-readable trajectory: BENCH_throughput.json ---------
    let json = Json::obj(vec![
        ("bench", Json::str("bench_throughput")),
        ("cores", Json::num(cores as f64)),
        ("thread_sweep", Json::Arr(sweep_json)),
        ("model_sweep", Json::Arr(models_json)),
        ("request_codec", Json::Arr(codec_json)),
    ]);
    let out = "BENCH_throughput.json";
    tensorserve::util::bench::write_bench_json(out, &json.to_string_pretty());
}
