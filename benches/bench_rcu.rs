//! Experiment T8 — §2.1.2: "Read-copy-update data structure to ensure
//! wait-free access to servables by inference threads."
//!
//! Serving-map lookups under three synchronization schemes — our RCU,
//! `std::sync::RwLock`, `std::sync::Mutex` — while a writer replaces a
//! 1000-entry map continuously (version churn). The claim to reproduce
//! is about the READ TAIL: RCU readers never wait for the writer (they
//! pin and read the old map), while lock-based readers stall whenever
//! the writer holds the lock mid-update. We therefore report read
//! latency percentiles, not just throughput.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};
use tensorserve::util::bench::{fmt_count, Table};
use tensorserve::util::metrics::{fmt_nanos, Histogram};
use tensorserve::util::rcu::Rcu;

type Map = HashMap<String, u64>;
const MAP_SIZE: usize = 1000;

fn base_map() -> Map {
    (0..MAP_SIZE as u64).map(|i| (format!("model-{i}"), i)).collect()
}

struct CaseResult {
    reads_per_sec: f64,
    hist: Histogram,
}

/// 4 reader threads measuring per-read latency; 1 writer continuously
/// replacing the map (if `with_writer`).
fn run_case<R, W>(dur: Duration, with_writer: bool, read: R, write_op: W) -> CaseResult
where
    R: Fn(&str) -> u64 + Send + Sync + 'static,
    W: Fn() + Send + Sync + 'static,
{
    let keys: Arc<Vec<String>> =
        Arc::new((0..MAP_SIZE).map(|i| format!("model-{i}")).collect());
    let stop = Arc::new(AtomicBool::new(false));
    let read = Arc::new(read);
    let write_op = Arc::new(write_op);
    let hist = Arc::new(Histogram::new());

    let mut handles = Vec::new();
    for t in 0..4usize {
        let stop = Arc::clone(&stop);
        let read = Arc::clone(&read);
        let keys = Arc::clone(&keys);
        let hist = Arc::clone(&hist);
        handles.push(std::thread::spawn(move || {
            let mut i = t;
            while !stop.load(Ordering::Relaxed) {
                let key = &keys[i % MAP_SIZE];
                let t0 = Instant::now();
                std::hint::black_box(read(key));
                hist.record_duration(t0.elapsed());
                i += 7;
            }
        }));
    }
    if with_writer {
        let stop = Arc::clone(&stop);
        let write_op = Arc::clone(&write_op);
        handles.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                write_op();
                std::thread::yield_now();
            }
        }));
    }
    std::thread::sleep(dur);
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        let _ = h.join();
    }
    let hist = Arc::try_unwrap(hist).unwrap_or_else(|_| panic!("hist still shared"));
    CaseResult { reads_per_sec: hist.count() as f64 / dur.as_secs_f64(), hist }
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let dur = tensorserve::util::bench::bench_duration(Duration::from_secs(2));
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("testbed: {cores} core(s); map of {MAP_SIZE} entries; writer clones+replaces it in a loop");

    let mut table = Table::new(
        "T8: serving-map read latency under continuous version churn (4 readers, 1 writer)",
        &["scheme", "reads/s", "p50", "p99", "p99.9", "max"],
    );

    let mut row = |label: &str, r: CaseResult| {
        let (p50, _, p99, p999) = r.hist.percentiles();
        table.row(vec![
            label.into(),
            fmt_count(r.reads_per_sec),
            fmt_nanos(p50),
            fmt_nanos(p99),
            fmt_nanos(p999),
            fmt_nanos(r.hist.max()),
        ]);
    };

    // --- RCU -----------------------------------------------------------
    {
        let cell = Arc::new(Rcu::new(base_map()));
        let c1 = Arc::clone(&cell);
        let c2 = Arc::clone(&cell);
        row(
            "RCU (ours)",
            run_case(
                dur,
                true,
                move |k| *c1.read().get(k).unwrap(),
                move || c2.rcu(|m| m.clone()),
            ),
        );
    }
    // --- RwLock ----------------------------------------------------------
    {
        let cell = Arc::new(RwLock::new(base_map()));
        let c1 = Arc::clone(&cell);
        let c2 = Arc::clone(&cell);
        row(
            "RwLock",
            run_case(
                dur,
                true,
                move |k| *c1.read().unwrap().get(k).unwrap(),
                move || {
                    // Writer holds the write lock while cloning 1000
                    // entries — the stall readers eat.
                    let mut g = c2.write().unwrap();
                    let snapshot = g.clone();
                    *g = snapshot;
                },
            ),
        );
    }
    // --- Mutex -----------------------------------------------------------
    {
        let cell = Arc::new(Mutex::new(base_map()));
        let c1 = Arc::clone(&cell);
        let c2 = Arc::clone(&cell);
        row(
            "Mutex",
            run_case(
                dur,
                true,
                move |k| *c1.lock().unwrap().get(k).unwrap(),
                move || {
                    let mut g = c2.lock().unwrap();
                    let snapshot = g.clone();
                    *g = snapshot;
                },
            ),
        );
    }
    // --- no-writer baselines ---------------------------------------------
    {
        let cell = Arc::new(Rcu::new(base_map()));
        let c1 = Arc::clone(&cell);
        row(
            "RCU (no writer)",
            run_case(dur, false, move |k| *c1.read().get(k).unwrap(), || {}),
        );
        let cell = Arc::new(RwLock::new(base_map()));
        let c1 = Arc::clone(&cell);
        row(
            "RwLock (no writer)",
            run_case(dur, false, move |k| *c1.read().unwrap().get(k).unwrap(), || {}),
        );
    }
    table.print();
    println!(
        "\nshape check: under churn, lock-based read p99/p99.9 absorbs the writer's\n\
         hold time (map clone) while RCU's read tail stays at its no-writer level —\n\
         \"wait-free access to servables by inference threads\"."
    );
}
