//! Experiment N1 — connection scaling: reactor vs threaded I/O plane.
//!
//! The reactor exists so connection count stops costing OS threads.
//! This bench pins the claim with numbers: keep-alive Ping round trips
//! (the pure net-plane path: framing → reactor → worker dispatch →
//! reply flush, no device work) at 64 / 512 / 2048 concurrent
//! connections, once over the epoll reactor and once over the legacy
//! thread-per-connection loops, reporting req/s, p50/p99, and how many
//! OS threads the server grew by under load.
//!
//! Emits BENCH_net.json for the perf trajectory.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tensorserve::net::sys::{process_thread_count, raise_nofile_limit};
use tensorserve::net::{NetConfig, NetMode};
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::ServerConfig;
use tensorserve::util::bench::{bench_duration, fmt_count, Table};
use tensorserve::util::json::Json;
use tensorserve::util::metrics::Histogram;

const DRIVERS: usize = 8;

fn server_with(mode: NetMode) -> Arc<ModelServer> {
    ModelServer::start(ServerConfig {
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        net: NetConfig {
            mode,
            reactor_threads: 2,
            worker_threads: 8,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap()
}

/// req/s + latency histogram + server thread growth for one
/// (mode, connection-count) cell.
fn run_cell(mode: NetMode, conns: usize, dur: Duration) -> (f64, u64, u64, usize) {
    let server = server_with(mode);
    let addr = server.addr().to_string();
    let threads_idle = process_thread_count().unwrap_or(0);

    // All connections up front, paced so the accept side keeps up with
    // the listener backlog.
    let mut clients = Vec::with_capacity(conns);
    for i in 0..conns {
        clients.push(RpcClient::connect(&addr).unwrap());
        if i % 64 == 63 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    std::thread::sleep(Duration::from_millis(50));
    let threads_loaded = process_thread_count().unwrap_or(0);

    // Each driver thread round-robins its share of the connections so
    // every connection stays live keep-alive traffic for the whole
    // window (DRIVERS requests in flight at a time).
    let latency = Arc::new(Histogram::new());
    let deadline = Instant::now() + dur;
    let mut shards: Vec<Vec<RpcClient>> = (0..DRIVERS).map(|_| Vec::new()).collect();
    for (i, c) in clients.into_iter().enumerate() {
        shards[i % DRIVERS].push(c);
    }
    let handles: Vec<_> = shards
        .into_iter()
        .map(|mut shard| {
            let latency = Arc::clone(&latency);
            std::thread::spawn(move || -> u64 {
                let mut count = 0u64;
                let mut i = 0usize;
                while Instant::now() < deadline {
                    let c = &mut shard[i % shard.len()];
                    i += 1;
                    let t0 = Instant::now();
                    let resp = c.call_ok(&Request::Ping).unwrap();
                    latency.record_duration(t0.elapsed());
                    assert!(matches!(resp, Response::Pong));
                    count += 1;
                }
                count
            })
        })
        .collect();
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    server.stop();

    let qps = total as f64 / dur.as_secs_f64();
    let (p50, _, p99, _) = latency.percentiles();
    (qps, p50, p99, threads_loaded.saturating_sub(threads_idle))
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let dur = bench_duration(Duration::from_secs(2));
    // Smoke mode is a compile-and-run guard: one tiny cell per mode.
    let conn_counts: &[usize] = if tensorserve::util::bench::smoke() {
        &[8]
    } else {
        &[64, 512, 2048]
    };
    // Client + server fds both live here: ~2 per connection.
    let limit = raise_nofile_limit(8192);
    let max_conns = (limit as usize / 2).saturating_sub(128);

    let mut t = Table::new(
        "N1: keep-alive Ping scaling, reactor vs thread-per-connection",
        &["mode", "conns", "req/s", "p50", "p99", "server thread growth"],
    );
    let mut cells = Vec::new();
    for &mode in &[NetMode::Reactor, NetMode::Threaded] {
        for &conns in conn_counts {
            if conns > max_conns {
                println!("skipping {mode:?}/{conns}: nofile limit {limit}");
                continue;
            }
            let (qps, p50, p99, grew) = run_cell(mode, conns, dur);
            let mode_name = match mode {
                NetMode::Reactor => "reactor",
                NetMode::Threaded => "threaded",
            };
            t.row(vec![
                mode_name.to_string(),
                conns.to_string(),
                fmt_count(qps),
                tensorserve::util::metrics::fmt_nanos(p50),
                tensorserve::util::metrics::fmt_nanos(p99),
                grew.to_string(),
            ]);
            cells.push(Json::obj(vec![
                ("mode", Json::str(mode_name)),
                ("conns", Json::num(conns as f64)),
                ("requests_per_sec", Json::num(qps)),
                ("p50_ns", Json::num(p50 as f64)),
                ("p99_ns", Json::num(p99 as f64)),
                ("server_thread_growth", Json::num(grew as f64)),
            ]));
        }
    }
    t.print();

    let json = Json::obj(vec![
        ("bench", Json::str("bench_net")),
        ("driver_threads", Json::num(DRIVERS as f64)),
        ("cells", Json::Arr(cells)),
    ]);
    tensorserve::util::bench::write_bench_json("BENCH_net.json", &json.to_string_pretty());
}
