//! Experiment T4 — §2.1.2's two version-transition policies:
//!
//! * availability-preserving (load new before unloading old): zero
//!   availability gap, peak RAM holds TWO versions;
//! * resource-preserving (unload old before loading new): peak RAM
//!   holds ONE version, with a measurable availability gap.
//!
//! We transition a 192MB "model" v1 → v2 under each policy, sampling
//! ready-version availability and process RSS throughout, and report
//! peak RSS delta and the availability-gap duration. Canary (both
//! versions aspired) is included as the §2.1.1 special case.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use tensorserve::base::aspired::{AspiredVersionsCallback, ServableData};
use tensorserve::base::loader::{FnLoader, Loader, ResourceEstimate};
use tensorserve::base::servable::{ServableBox, ServableId};
use tensorserve::lifecycle::basic_manager::ManagerOptions;
use tensorserve::lifecycle::manager::{AspiredVersionsManager, AvmOptions};
use tensorserve::lifecycle::policy::{
    AvailabilityPreservingPolicy, ResourcePreservingPolicy, VersionPolicy,
};
use tensorserve::util::bench::Table;
use tensorserve::util::mem::{current_rss_bytes, WeightBlob};

/// 192MB model; 16MB in bench-smoke mode (compile+run guard).
fn blob_bytes() -> usize {
    if tensorserve::util::bench::smoke() {
        16 << 20
    } else {
        192 << 20
    }
}

fn blob_loader() -> Arc<dyn Loader> {
    let bytes = blob_bytes();
    Arc::new(FnLoader::new(
        ResourceEstimate::ram(bytes as u64),
        "blob",
        move || {
            let blob = WeightBlob::new(bytes);
            std::hint::black_box(blob.checksum());
            Ok(Arc::new(blob) as ServableBox)
        },
    ))
}

fn aspire(avm: &Arc<AspiredVersionsManager>, versions: &[u64]) {
    let data = versions
        .iter()
        .map(|&v| ServableData::ok(ServableId::new("m", v), blob_loader()))
        .collect();
    avm.set_aspired_versions("m", data);
}

struct TransitionStats {
    peak_rss_delta_mb: f64,
    gap: Duration,
    total: Duration,
    max_ready: usize,
}

/// Run v1 → transition under `policy`. `canary`: aspire both versions
/// (the §2.1.1 flow) instead of replacing.
fn run_transition(policy: Arc<dyn VersionPolicy>, canary: bool) -> TransitionStats {
    let avm = AspiredVersionsManager::new(
        policy,
        AvmOptions {
            manager: ManagerOptions { load_threads: 2, name: "bench".into(), ..Default::default() },
            reconcile_interval: Some(Duration::from_millis(5)),
        },
    );
    aspire(&avm, &[1]);
    let deadline = Instant::now() + Duration::from_secs(60);
    while avm.basic().ready_versions("m") != vec![1] {
        assert!(Instant::now() < deadline, "v1 never loaded");
        std::thread::sleep(Duration::from_millis(5));
    }
    tensorserve::util::mem::release_to_os();
    std::thread::sleep(Duration::from_millis(50));
    let rss_baseline = current_rss_bytes();

    // Sample availability + RSS at 1ms while the transition runs.
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let avm = Arc::clone(&avm);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut peak_rss = 0u64;
            let mut gap = Duration::ZERO;
            let mut max_ready = 0usize;
            let mut last = Instant::now();
            while !stop.load(Ordering::Relaxed) {
                let ready = avm.basic().ready_versions("m").len();
                max_ready = max_ready.max(ready);
                let now = Instant::now();
                if ready == 0 {
                    gap += now - last;
                }
                last = now;
                peak_rss = peak_rss.max(current_rss_bytes());
                std::thread::sleep(Duration::from_millis(1));
            }
            (peak_rss, gap, max_ready)
        })
    };

    let t0 = Instant::now();
    if canary {
        aspire(&avm, &[1, 2]);
        let want = vec![1, 2];
        while avm.basic().ready_versions("m") != want {
            assert!(Instant::now() < deadline, "canary never completed");
            std::thread::sleep(Duration::from_millis(2));
        }
    } else {
        aspire(&avm, &[2]);
        while avm.basic().ready_versions("m") != vec![2] {
            assert!(Instant::now() < deadline, "transition never completed");
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let total = t0.elapsed();
    avm.basic().quiesce();
    stop.store(true, Ordering::Relaxed);
    let (peak_rss, gap, max_ready) = sampler.join().unwrap();

    TransitionStats {
        peak_rss_delta_mb: (peak_rss.saturating_sub(rss_baseline)) as f64 / (1 << 20) as f64,
        gap,
        total,
        max_ready,
    }
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let mut t = Table::new(
        &format!(
            "T4: version transition v1->v2 of a {}MB model (RSS sampled @1ms)",
            blob_bytes() >> 20
        ),
        &[
            "policy",
            "peak RSS over baseline",
            "availability gap",
            "max simultaneous versions",
            "transition time",
        ],
    );
    let cases: Vec<(&str, Arc<dyn VersionPolicy>, bool)> = vec![
        ("availability-preserving", Arc::new(AvailabilityPreservingPolicy), false),
        ("resource-preserving", Arc::new(ResourcePreservingPolicy), false),
        ("canary (both aspired)", Arc::new(AvailabilityPreservingPolicy), true),
    ];
    for (label, policy, canary) in cases {
        let s = run_transition(policy, canary);
        t.row(vec![
            label.into(),
            format!("{:.0} MB", s.peak_rss_delta_mb),
            format!("{:.1} ms", s.gap.as_secs_f64() * 1e3),
            s.max_ready.to_string(),
            format!("{:.0} ms", s.total.as_secs_f64() * 1e3),
        ]);
    }
    t.print();
    println!(
        "\nshape check: availability-preserving ⇒ ~2x peak RAM (~+192MB), 0ms gap;\n\
         resource-preserving ⇒ ~1x peak RAM, gap > 0 (unload-then-load window);\n\
         canary holds both versions (like availability-preserving, by design)."
    );
}
