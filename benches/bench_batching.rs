//! Experiment T3 — §2.2.1: batching "can boost throughput
//! substantially, but it has to be managed carefully to avoid unduly
//! hurting latency", with dynamic queues scheduled "in a round-robin
//! fashion onto a single shared device".
//!
//! Device model: an accelerator-like executor whose service time is
//! `base + per_row · rows` (dispatch overhead amortizes over the merged
//! batch — the reason batching exists). We sweep `max_batch_size` and
//! `batch_timeout` under an open-loop load and report throughput and
//! latency percentiles, then check round-robin fairness across two
//! model queues sharing one device thread.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};
use tensorserve::batching::batch::BatchTask;
use tensorserve::batching::scheduler::{QueueOptions, SchedulerOptions, SharedBatchScheduler};
use tensorserve::util::bench::{fmt_count, Table};
use tensorserve::util::metrics::{fmt_nanos, Histogram};
use tensorserve::util::rng::Rng;

/// Simulated accelerator: 150µs dispatch + 4µs/row.
const DISPATCH: Duration = Duration::from_micros(150);
const PER_ROW: Duration = Duration::from_micros(4);

struct Req {
    arrived: Instant,
    done: mpsc::Sender<Duration>,
}

impl BatchTask for Req {
    fn size(&self) -> usize {
        1
    }
}

/// Drive `rate` qps of single-row requests for `dur` through one queue.
fn run_config(
    max_batch: usize,
    timeout: Duration,
    rate: f64,
    dur: Duration,
) -> (f64, Histogram, f64) {
    let sched = SharedBatchScheduler::<Req>::new(SchedulerOptions {
        num_batch_threads: 1, // one shared device
        name: "bench".into(),
    });
    let batches = Arc::new(AtomicU64::new(0));
    let rows = Arc::new(AtomicU64::new(0));
    let b2 = Arc::clone(&batches);
    let r2 = Arc::clone(&rows);
    let queue = sched.add_queue(
        "m",
        QueueOptions {
            max_batch_size: max_batch,
            batch_timeout: timeout,
            max_enqueued_batches: 1 << 20,
        },
        move |batch| {
            // The merged device call.
            std::thread::sleep(DISPATCH + PER_ROW * batch.len() as u32);
            b2.fetch_add(1, Ordering::Relaxed);
            r2.fetch_add(batch.len() as u64, Ordering::Relaxed);
            for task in batch.into_tasks() {
                let _ = task.done.send(task.arrived.elapsed());
            }
        },
    );

    let (lat_tx, lat_rx) = mpsc::channel::<Duration>();
    let hist = Histogram::new();
    let collector = std::thread::spawn({
        let hist: *const Histogram = &hist;
        let hist = unsafe { &*hist }; // joined before hist drops
        move || {
            for d in lat_rx {
                hist.record_duration(d);
            }
        }
    });

    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    let mut next = t0;
    let mut sent = 0u64;
    while t0.elapsed() < dur {
        let now = Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        let _ = queue.enqueue(Req { arrived: Instant::now(), done: lat_tx.clone() });
        sent += 1;
        next += Duration::from_secs_f64(rng.exponential(1.0 / rate));
    }
    sched.quiesce();
    drop(lat_tx);
    let elapsed = t0.elapsed();
    collector.join().unwrap();
    let mean_batch =
        rows.load(Ordering::Relaxed) as f64 / batches.load(Ordering::Relaxed).max(1) as f64;
    (sent as f64 / elapsed.as_secs_f64(), hist, mean_batch)
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let dur = Duration::from_secs(3);

    // Offered load: 4000 qps. Unbatched capacity is only
    // 1/(150µs+4µs) ≈ 6.5k qps of *device* time per row-call, but each
    // call pays the dispatch: batching is what keeps the device ahead.
    let rate = 4000.0;
    let mut t = Table::new(
        &format!("T3: batch-size / timeout sweep @ {rate} qps offered (device: 150us + 4us/row)"),
        &["max_batch", "timeout", "tput qps", "mean batch", "p50", "p99", "p99.9"],
    );
    for (max_batch, timeout_us) in [
        (1, 0u64),
        (4, 500),
        (16, 500),
        (64, 500),
        (64, 2000),
        (64, 10000),
    ] {
        let (tput, hist, mean_batch) =
            run_config(max_batch, Duration::from_micros(timeout_us), rate, dur);
        let (p50, _, p99, p999) = hist.percentiles();
        t.row(vec![
            max_batch.to_string(),
            format!("{}us", timeout_us),
            fmt_count(tput),
            format!("{mean_batch:.1}"),
            fmt_nanos(p50),
            fmt_nanos(p99),
            fmt_nanos(p999),
        ]);
    }
    t.print();
    println!(
        "\nshape check: max_batch=1 saturates (queueing blow-up at the tail);\n\
         larger batches recover throughput; oversized timeouts trade p50 for nothing."
    );

    // ---- round-robin fairness across model queues --------------------
    let sched = SharedBatchScheduler::<Req>::new(SchedulerOptions {
        num_batch_threads: 1,
        name: "fair".into(),
    });
    let counts = Arc::new(Mutex::new([0u64; 2]));
    let queues: Vec<_> = (0..2)
        .map(|i| {
            let counts = Arc::clone(&counts);
            sched.add_queue(
                &format!("m{i}"),
                QueueOptions {
                    max_batch_size: 8,
                    batch_timeout: Duration::from_micros(200),
                    max_enqueued_batches: 1 << 20,
                },
                move |batch| {
                    std::thread::sleep(DISPATCH + PER_ROW * batch.len() as u32);
                    counts.lock().unwrap()[i] += batch.len() as u64;
                    for task in batch.into_tasks() {
                        let _ = task.done.send(task.arrived.elapsed());
                    }
                },
            )
        })
        .collect();
    let (tx, rx) = mpsc::channel();
    drop(rx); // fairness run ignores latencies
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_secs(2) {
        for q in &queues {
            let _ = q.enqueue(Req { arrived: Instant::now(), done: tx.clone() });
        }
        std::thread::sleep(Duration::from_micros(250));
    }
    sched.quiesce();
    let c = counts.lock().unwrap();
    let mut t = Table::new(
        "T3b: round-robin fairness, 2 equal-load model queues on 1 shared device",
        &["queue", "rows served", "share"],
    );
    let total = (c[0] + c[1]).max(1);
    for i in 0..2 {
        t.row(vec![
            format!("m{i}"),
            c[i].to_string(),
            format!("{:.1}%", 100.0 * c[i] as f64 / total as f64),
        ]);
    }
    t.print();
    println!("\nshape check: shares should be ~50/50 (round-robin interleaving).");
}
