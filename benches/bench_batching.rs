//! Experiment T3 — §2.2.1: batching "can boost throughput
//! substantially, but it has to be managed carefully to avoid unduly
//! hurting latency", with dynamic queues scheduled "in a round-robin
//! fashion onto a single shared device".
//!
//! Device model: an accelerator-like executor whose service time is
//! `base + per_row · rows` (dispatch overhead amortizes over the merged
//! batch — the reason batching exists). We sweep `max_batch_size` and
//! `batch_timeout` under an open-loop load and report throughput and
//! latency percentiles, then check round-robin fairness across two
//! model queues sharing one device thread.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Barrier, Mutex};
use std::time::{Duration, Instant};
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::batching::batch::BatchTask;
use tensorserve::batching::scheduler::{QueueOptions, SchedulerOptions, SharedBatchScheduler};
use tensorserve::inference::predict::{predict_with, PredictRequest};
use tensorserve::lifecycle::basic_manager::{BasicManager, VersionRequest};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::runtime::hlo_servable::{synthetic_loader, HloServable};
use tensorserve::serving::{BatchingConfig, DirectRunner, Runner, SessionRegistry};
use tensorserve::util::bench::{bench_duration, fmt_count, measure, ns_per_iter, smoke, Table};
use tensorserve::util::json::Json;
use tensorserve::util::metrics::{fmt_nanos, Histogram, Registry};
use tensorserve::util::pool::BufferPool;
use tensorserve::util::rng::Rng;

/// Simulated accelerator: 150µs dispatch + 4µs/row.
const DISPATCH: Duration = Duration::from_micros(150);
const PER_ROW: Duration = Duration::from_micros(4);

struct Req {
    arrived: Instant,
    done: mpsc::Sender<Duration>,
}

impl BatchTask for Req {
    fn size(&self) -> usize {
        1
    }
}

/// Drive `rate` qps of single-row requests for `dur` through one queue.
fn run_config(
    max_batch: usize,
    timeout: Duration,
    rate: f64,
    dur: Duration,
) -> (f64, Histogram, f64) {
    let sched = SharedBatchScheduler::<Req>::new(SchedulerOptions {
        num_batch_threads: 1, // one shared device
        name: "bench".into(),
    });
    let batches = Arc::new(AtomicU64::new(0));
    let rows = Arc::new(AtomicU64::new(0));
    let b2 = Arc::clone(&batches);
    let r2 = Arc::clone(&rows);
    let queue = sched.add_queue(
        "m",
        QueueOptions {
            max_batch_size: max_batch,
            batch_timeout: timeout,
            max_enqueued_batches: 1 << 20,
            ..Default::default()
        },
        move |batch| {
            // The merged device call.
            std::thread::sleep(DISPATCH + PER_ROW * batch.len() as u32);
            b2.fetch_add(1, Ordering::Relaxed);
            r2.fetch_add(batch.len() as u64, Ordering::Relaxed);
            for task in batch.into_tasks() {
                let _ = task.done.send(task.arrived.elapsed());
            }
        },
    );

    let (lat_tx, lat_rx) = mpsc::channel::<Duration>();
    let hist = Histogram::new();
    let collector = std::thread::spawn({
        let hist: *const Histogram = &hist;
        let hist = unsafe { &*hist }; // joined before hist drops
        move || {
            for d in lat_rx {
                hist.record_duration(d);
            }
        }
    });

    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    let mut next = t0;
    let mut sent = 0u64;
    while t0.elapsed() < dur {
        let now = Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        let _ = queue.enqueue(Req { arrived: Instant::now(), done: lat_tx.clone() });
        sent += 1;
        next += Duration::from_secs_f64(rng.exponential(1.0 / rate));
    }
    sched.quiesce();
    drop(lat_tx);
    let elapsed = t0.elapsed();
    collector.join().unwrap();
    let mean_batch =
        rows.load(Ordering::Relaxed) as f64 / batches.load(Ordering::Relaxed).max(1) as f64;
    (sent as f64 / elapsed.as_secs_f64(), hist, mean_batch)
}

/// T3e worker harness: `threads` threads hammering acquire/release on
/// a pool with `shards` lock stripes. Returns combined ops/sec.
/// `shards = 1` reproduces the pre-sharding single-mutex shelf.
fn pool_contention_ops(threads: usize, shards: usize, dur: Duration) -> f64 {
    let pool: Arc<BufferPool> = Arc::new(BufferPool::with_shards(32, 1 << 30, shards));
    let stop = Arc::new(AtomicBool::new(false));
    let ops = Arc::new(AtomicU64::new(0));
    let start = Arc::new(Barrier::new(threads + 1));
    let workers: Vec<_> = (0..threads)
        .map(|_| {
            let pool = Arc::clone(&pool);
            let stop = Arc::clone(&stop);
            let ops = Arc::clone(&ops);
            let start = Arc::clone(&start);
            std::thread::spawn(move || {
                // Warm this thread's home shard so the steady state is
                // all hits (the serving steady state).
                pool.release(pool.acquire(1024));
                start.wait();
                let mut local = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let buf = pool.acquire(1024);
                    std::hint::black_box(&buf);
                    pool.release(buf);
                    local += 1;
                }
                ops.fetch_add(local, Ordering::Relaxed);
            })
        })
        .collect();
    start.wait();
    std::thread::sleep(dur);
    stop.store(true, Ordering::Relaxed);
    for w in workers {
        w.join().unwrap();
    }
    ops.load(Ordering::Relaxed) as f64 / dur.as_secs_f64()
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let dur = bench_duration(Duration::from_secs(3));

    // Offered load: 4000 qps. Unbatched capacity is only
    // 1/(150µs+4µs) ≈ 6.5k qps of *device* time per row-call, but each
    // call pays the dispatch: batching is what keeps the device ahead.
    let rate = 4000.0;
    let mut t = Table::new(
        &format!("T3: batch-size / timeout sweep @ {rate} qps offered (device: 150us + 4us/row)"),
        &["max_batch", "timeout", "tput qps", "mean batch", "p50", "p99", "p99.9"],
    );
    let mut sweep_json = Vec::new();
    for (max_batch, timeout_us) in [
        (1, 0u64),
        (4, 500),
        (16, 500),
        (64, 500),
        (64, 2000),
        (64, 10000),
    ] {
        let (tput, hist, mean_batch) =
            run_config(max_batch, Duration::from_micros(timeout_us), rate, dur);
        let (p50, _, p99, p999) = hist.percentiles();
        sweep_json.push(Json::obj(vec![
            ("max_batch", Json::num(max_batch as f64)),
            ("timeout_us", Json::num(timeout_us as f64)),
            ("throughput_qps", Json::num(tput)),
            ("batches_per_sec", Json::num(tput / mean_batch.max(1e-9))),
            ("mean_batch", Json::num(mean_batch)),
            ("p50_ns", Json::num(p50 as f64)),
            ("p99_ns", Json::num(p99 as f64)),
            ("p999_ns", Json::num(p999 as f64)),
        ]));
        t.row(vec![
            max_batch.to_string(),
            format!("{}us", timeout_us),
            fmt_count(tput),
            format!("{mean_batch:.1}"),
            fmt_nanos(p50),
            fmt_nanos(p99),
            fmt_nanos(p999),
        ]);
    }
    t.print();
    println!(
        "\nshape check: max_batch=1 saturates (queueing blow-up at the tail);\n\
         larger batches recover throughput; oversized timeouts trade p50 for nothing."
    );

    // ---- round-robin fairness across model queues --------------------
    let sched = SharedBatchScheduler::<Req>::new(SchedulerOptions {
        num_batch_threads: 1,
        name: "fair".into(),
    });
    let counts = Arc::new(Mutex::new([0u64; 2]));
    let queues: Vec<_> = (0..2)
        .map(|i| {
            let counts = Arc::clone(&counts);
            sched.add_queue(
                &format!("m{i}"),
                QueueOptions {
                    max_batch_size: 8,
                    batch_timeout: Duration::from_micros(200),
                    max_enqueued_batches: 1 << 20,
                    ..Default::default()
                },
                move |batch| {
                    std::thread::sleep(DISPATCH + PER_ROW * batch.len() as u32);
                    counts.lock().unwrap()[i] += batch.len() as u64;
                    for task in batch.into_tasks() {
                        let _ = task.done.send(task.arrived.elapsed());
                    }
                },
            )
        })
        .collect();
    let (tx, rx) = mpsc::channel();
    drop(rx); // fairness run ignores latencies
    let fair_dur = bench_duration(Duration::from_secs(2));
    let t0 = Instant::now();
    while t0.elapsed() < fair_dur {
        for q in &queues {
            let _ = q.enqueue(Req { arrived: Instant::now(), done: tx.clone() });
        }
        std::thread::sleep(Duration::from_micros(250));
    }
    sched.quiesce();
    let c = counts.lock().unwrap();
    let mut t = Table::new(
        "T3b: round-robin fairness, 2 equal-load model queues on 1 shared device",
        &["queue", "rows served", "share"],
    );
    let total = (c[0] + c[1]).max(1);
    for i in 0..2 {
        t.row(vec![
            format!("m{i}"),
            c[i].to_string(),
            format!("{:.1}%", 100.0 * c[i] as f64 / total as f64),
        ]);
    }
    t.print();
    println!("\nshape check: shares should be ~50/50 (round-robin interleaving).");

    // ---- T3c: tensor assembly — naive copy chain vs fused pooled path
    //
    // The hot-path work `BatchingSession::process` does per merged
    // batch, isolated from scheduling: the pre-view implementation
    // copied the batch ~5× (clone per task, concat, pad, truncate,
    // split); the fused path writes each request's rows once into a
    // pooled device buffer and scatters outputs as zero-copy views.
    const REQS: usize = 8; // requests per merged batch
    const ROWS: usize = 2; // rows per request
    const DIM: usize = 32; // features per row
    const TARGET: usize = 16; // padded ladder size (REQS*ROWS -> 16)
    let inputs: Vec<Tensor> = (0..REQS)
        .map(|i| Tensor::matrix(vec![vec![i as f32; DIM]; ROWS]).unwrap())
        .collect();
    let sizes: Vec<usize> = inputs.iter().map(Tensor::batch).collect();
    let merged_rows: usize = sizes.iter().sum();

    // The old chain, byte-for-byte: every stage materializes a copy.
    let naive = |inputs: &[Tensor]| {
        let cloned: Vec<Tensor> = inputs
            .iter()
            .map(|t| Tensor::new(t.shape().to_vec(), t.data().to_vec()).unwrap())
            .collect();
        let merged = Tensor::concat(&cloned).unwrap();
        let mut padded = merged.data().to_vec();
        padded.resize(TARGET * DIM, 0.0);
        let padded = Tensor::new(vec![TARGET, DIM], padded).unwrap();
        // (device call elided — this isolates framework data movement)
        let trimmed =
            Tensor::new(vec![merged_rows, DIM], padded.data()[..merged_rows * DIM].to_vec())
                .unwrap();
        let mut off = 0usize;
        let parts: Vec<Tensor> = sizes
            .iter()
            .map(|&s| {
                let p = Tensor::new(
                    vec![s, DIM],
                    trimmed.data()[off * DIM..(off + s) * DIM].to_vec(),
                )
                .unwrap();
                off += s;
                p
            })
            .collect();
        std::hint::black_box(parts);
    };

    // The fused path: one pooled buffer, one copy in, views out.
    let pool = BufferPool::new(8, 1 << 24);
    let fused = |inputs: &[Tensor]| {
        let merged = Tensor::build_with(vec![TARGET, DIM], &pool, |buf| {
            let mut off = 0usize;
            for t in inputs {
                let d = t.data();
                buf[off..off + d.len()].copy_from_slice(d);
                off += d.len();
            }
            buf[off..].fill(0.0);
        });
        let trimmed = merged.truncate_batch(merged_rows).unwrap();
        let parts = trimmed.split(&sizes).unwrap();
        std::hint::black_box(&parts);
        drop(parts);
        drop(trimmed);
        merged.recycle_into(&pool);
    };

    let warmup = bench_duration(Duration::from_millis(100));
    let mdur = bench_duration(Duration::from_millis(800));
    let (it_naive, el_naive) = measure(warmup, mdur, || naive(&inputs));
    let (it_fused, el_fused) = measure(warmup, mdur, || fused(&inputs));
    let naive_batch_ns = ns_per_iter(it_naive, el_naive);
    let fused_batch_ns = ns_per_iter(it_fused, el_fused);
    let row_bytes = DIM * std::mem::size_of::<f32>();
    // Bytes the framework copies per request: the naive chain moves the
    // payload in clone+concat+truncate+split and the whole padded
    // buffer once; fused moves the payload exactly once.
    let naive_bytes_per_req =
        (4 * merged_rows * row_bytes + TARGET * row_bytes) / REQS;
    let fused_bytes_per_req = merged_rows * row_bytes / REQS;

    let mut t = Table::new(
        &format!(
            "T3c: batch assembly, {REQS} reqs x {ROWS}x{DIM} rows, pad to {TARGET} \
             (naive = pre-view copy chain; fused = pooled single-allocation)"
        ),
        &["path", "ns/batch", "ns/request", "bytes copied/req", "pool hit rate"],
    );
    let stats = pool.stats();
    let hit_rate = stats.hits as f64 / (stats.hits + stats.misses).max(1) as f64;
    t.row(vec![
        "naive".into(),
        format!("{naive_batch_ns:.0}"),
        format!("{:.0}", naive_batch_ns / REQS as f64),
        naive_bytes_per_req.to_string(),
        "-".into(),
    ]);
    t.row(vec![
        "fused".into(),
        format!("{fused_batch_ns:.0}"),
        format!("{:.0}", fused_batch_ns / REQS as f64),
        fused_bytes_per_req.to_string(),
        format!("{:.1}%", 100.0 * hit_rate),
    ]);
    t.print();
    println!(
        "\nshape check: fused should beat naive (~{:.1}x here) and hit rate ~100%.",
        naive_batch_ns / fused_batch_ns
    );

    // ---- T3d: end-to-end merged throughput on the live serving path
    //
    // The real stack this time: manager + synthetic servable +
    // SessionRegistry, exactly what `ServerCore::handle` drives.
    // Baseline = one sequential client through DirectRunner (the old
    // unbatched path); merged = concurrent clients through the
    // registry, whose requests coalesce into shared device batches.
    // The merge ratio (requests per device execution) is the headline:
    // on accelerators, device time per request shrinks by that factor.
    let manager = BasicManager::with_defaults();
    let mut spec = ArtifactSpec::synthetic_classifier("merge", 1, 32, 4);
    spec.allowed_batch_sizes = vec![1, 4, 16, 64];
    manager
        .load_and_wait(
            ServableId::new("merge", 1),
            synthetic_loader(spec),
            Duration::from_secs(30),
        )
        .unwrap();
    let registry = SessionRegistry::new(
        BatchingConfig {
            max_batch_size: 64,
            batch_timeout: Duration::from_micros(200),
            ..Default::default()
        },
        Registry::new(),
    );
    registry.attach(&manager);
    let servable = manager
        .handle::<HloServable>("merge", VersionRequest::Latest)
        .unwrap();

    let request = |seed: usize| {
        let row: Vec<f32> = (0..32).map(|j| ((seed * 31 + j) as f32 * 0.37).sin()).collect();
        PredictRequest::single("merge", None, Tensor::matrix(vec![row]).unwrap())
    };
    let seq_reqs: usize = if smoke() { 100 } else { 2_000 };
    const CLIENTS: usize = 8;
    let per_client: usize = if smoke() { 50 } else { 1_000 };

    // Sequential direct baseline.
    let t0 = Instant::now();
    for i in 0..seq_reqs {
        predict_with(manager.as_ref(), &DirectRunner, &request(i)).unwrap();
    }
    let seq_qps = seq_reqs as f64 / t0.elapsed().as_secs_f64();

    // Concurrent clients through the session registry.
    let execs_before = servable.executions();
    let t0 = Instant::now();
    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let manager = Arc::clone(&manager);
            let registry = Arc::clone(&registry);
            std::thread::spawn(move || {
                for i in 0..per_client {
                    predict_with(
                        manager.as_ref(),
                        registry.as_ref() as &dyn Runner,
                        &request(c * per_client + i),
                    )
                    .unwrap();
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    let merged_elapsed = t0.elapsed();
    let merged_reqs = (CLIENTS * per_client) as f64;
    let merged_qps = merged_reqs / merged_elapsed.as_secs_f64();
    let merged_execs = (servable.executions() - execs_before) as f64;
    let merge_ratio = merged_reqs / merged_execs.max(1.0);

    let mut t = Table::new(
        &format!(
            "T3d: serving-path merge, {CLIENTS} concurrent clients vs sequential baseline \
             (synthetic model, b=1 requests)"
        ),
        &["path", "requests", "device execs", "reqs/exec", "qps"],
    );
    t.row(vec![
        "sequential direct".into(),
        seq_reqs.to_string(),
        seq_reqs.to_string(),
        "1.0".into(),
        fmt_count(seq_qps),
    ]);
    t.row(vec![
        "concurrent merged".into(),
        format!("{}", CLIENTS * per_client),
        format!("{merged_execs:.0}"),
        format!("{merge_ratio:.1}"),
        fmt_count(merged_qps),
    ]);
    t.print();
    println!(
        "\nshape check: reqs/exec ≫ 1 (cross-request merging live); on a real \
         accelerator the device-time saving tracks that ratio."
    );

    // ---- T3e: contended buffer pool — sharded vs single-mutex shelf
    //
    // M threads hammering acquire/release (what batch assembly + the
    // RPC/REST decode paths do under load). `shards = 1` is the
    // pre-sharding implementation: every op serializes on one shelf
    // mutex. The sharded pool stripes the shelves so each thread's
    // home shard has its own lock.
    let contend_dur = bench_duration(Duration::from_millis(600));
    let mut t = Table::new(
        "T3e: pool acquire/release throughput, M threads (1024-elem class, all hits)",
        &["threads", "1-shard Mops/s", "sharded Mops/s", "shards", "speedup"],
    );
    let mut contention_json = Vec::new();
    let mut speedup_at_8 = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let shards = tensorserve::util::pool::clamp_shards(threads);
        let single = pool_contention_ops(threads, 1, contend_dur);
        let sharded = pool_contention_ops(threads, shards, contend_dur);
        let speedup = sharded / single.max(1.0);
        if threads == 8 {
            speedup_at_8 = speedup;
        }
        contention_json.push(Json::obj(vec![
            ("threads", Json::num(threads as f64)),
            ("single_mutex_ops_per_sec", Json::num(single)),
            ("sharded_ops_per_sec", Json::num(sharded)),
            ("shards", Json::num(shards as f64)),
            ("speedup", Json::num(speedup)),
        ]));
        t.row(vec![
            threads.to_string(),
            format!("{:.2}", single / 1e6),
            format!("{:.2}", sharded / 1e6),
            shards.to_string(),
            format!("{speedup:.2}x"),
        ]);
    }
    t.print();
    println!(
        "\nshape check: sharded ≥ 2x the single-mutex baseline at 8 threads \
         (got {speedup_at_8:.2}x); 1-thread costs should be ~equal."
    );

    // ---- machine-readable trajectory: BENCH_batching.json -----------
    let json = Json::obj(vec![
        ("bench", Json::str("bench_batching")),
        ("t3_sweep", Json::Arr(sweep_json)),
        ("pool_contention", Json::Arr(contention_json)),
        ("pool_contention_speedup_8_threads", Json::num(speedup_at_8)),
        (
            "e2e_merge",
            Json::obj(vec![
                ("sequential_requests", Json::num(seq_reqs as f64)),
                ("sequential_qps", Json::num(seq_qps)),
                ("concurrent_clients", Json::num(CLIENTS as f64)),
                ("concurrent_requests", Json::num(merged_reqs)),
                ("concurrent_qps", Json::num(merged_qps)),
                ("device_executions", Json::num(merged_execs)),
                ("merge_ratio", Json::num(merge_ratio)),
            ]),
        ),
        (
            "assembly",
            Json::obj(vec![
                ("requests_per_batch", Json::num(REQS as f64)),
                ("rows_per_request", Json::num(ROWS as f64)),
                ("dim", Json::num(DIM as f64)),
                ("padded_target", Json::num(TARGET as f64)),
                ("naive_ns_per_batch", Json::num(naive_batch_ns)),
                ("fused_ns_per_batch", Json::num(fused_batch_ns)),
                ("naive_ns_per_request", Json::num(naive_batch_ns / REQS as f64)),
                ("fused_ns_per_request", Json::num(fused_batch_ns / REQS as f64)),
                ("speedup", Json::num(naive_batch_ns / fused_batch_ns.max(1e-9))),
                (
                    "naive_bytes_copied_per_request",
                    Json::num(naive_bytes_per_req as f64),
                ),
                (
                    "fused_bytes_copied_per_request",
                    Json::num(fused_bytes_per_req as f64),
                ),
                ("pool_hit_rate", Json::num(hit_rate)),
            ]),
        ),
    ]);
    let out = "BENCH_batching.json";
    tensorserve::util::bench::write_bench_json(out, &json.to_string_pretty());
}
