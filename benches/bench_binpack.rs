//! Experiment T7 — §3.1: the Controller "estimates the RAM required to
//! serve a given model and selects a serving job that has enough memory
//! capacity".
//!
//! Placement quality of best-fit-decreasing (ours) vs first-fit
//! (baseline) over realistic model-size mixes: many small models, some
//! large ones ("model accuracy improvements are sometimes won at the
//! cost of model bloat", §1 fn 1). Metrics: jobs used, utilization of
//! used jobs, models that failed to place.

use tensorserve::tfs2::binpack::{best_fit_decreasing, first_fit, utilization, Bin};
use tensorserve::util::bench::Table;
use tensorserve::util::rng::Rng;

const JOB_CAPACITY: u64 = 16 << 30; // 16 GB serving jobs

/// Model-size mix: 50% small (10-500MB), 30% medium (0.5-4GB),
/// 20% large (6-14GB) — §1 fn 1: "model bloat".
fn model_sizes(n: usize, seed: u64) -> Vec<(String, u64)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let mb: u64 = match rng.next_below(100) {
                0..=49 => 10 + rng.next_below(490),
                50..=79 => 512 + rng.next_below(3584),
                _ => 6144 + rng.next_below(8192),
            };
            (format!("model-{i}"), mb << 20)
        })
        .collect()
}

struct Outcome {
    jobs_used: usize,
    utilization: f64,
    failed: usize,
}

fn run_bfd(items: &[(String, u64)], jobs: usize) -> Outcome {
    let mut bins: Vec<Bin> =
        (0..jobs).map(|i| Bin::new(format!("job-{i}"), JOB_CAPACITY)).collect();
    let (_placed, failed) = best_fit_decreasing(&mut bins, items);
    Outcome {
        jobs_used: bins.iter().filter(|b| b.used > 0).count(),
        utilization: utilization(&bins),
        failed: failed.len(),
    }
}

fn run_first_fit(items: &[(String, u64)], jobs: usize) -> Outcome {
    let mut bins: Vec<Bin> =
        (0..jobs).map(|i| Bin::new(format!("job-{i}"), JOB_CAPACITY)).collect();
    let mut failed = 0;
    // Arrival order (no sorting) — the naive Controller.
    for (_, size) in items {
        match first_fit(&bins, *size) {
            Some(i) => bins[i].used += size,
            None => failed += 1,
        }
    }
    Outcome {
        jobs_used: bins.iter().filter(|b| b.used > 0).count(),
        utilization: utilization(&bins),
        failed,
    }
}

fn main() {
    let mut t = Table::new(
        "T7: model placement onto 16GB serving jobs — best-fit-decreasing (ours) vs first-fit",
        &["models", "jobs avail", "policy", "jobs used", "util of used", "failed"],
    );
    // Smoke mode keeps one small mix: compile+run guard only.
    let mixes: &[usize] = if tensorserve::util::bench::smoke() {
        &[50]
    } else {
        &[50, 200, 1000]
    };
    for &n_models in mixes {
        let items = model_sizes(n_models, 42 + n_models as u64);
        // Tight capacity: 2% headroom over the theoretical minimum —
        // the regime where placement quality decides what fits.
        let total: u64 = items.iter().map(|(_, s)| s).sum();
        let n_jobs = ((total as f64 / JOB_CAPACITY as f64) * 1.02).ceil() as usize;
        for (label, outcome) in [
            ("best-fit-dec", run_bfd(&items, n_jobs)),
            ("first-fit", run_first_fit(&items, n_jobs)),
        ] {
            t.row(vec![
                n_models.to_string(),
                n_jobs.to_string(),
                label.into(),
                outcome.jobs_used.to_string(),
                format!("{:.1}%", outcome.utilization * 100.0),
                outcome.failed.to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\nshape check: BFD packs the same models into fewer (or equal) jobs at higher\n\
         utilization, and strands fewer large models when capacity is tight."
    );
}
