//! Experiment H1 — the REST gateway's JSON-ingress cost.
//!
//! De Rosa et al. ("On the Cost of Model-Serving Frameworks") show the
//! REST path is where serving stacks typically lose most of their
//! throughput, so this bench tracks it as a first-class perf surface:
//!
//! * **codec**: ns/op to translate JSON instance rows into pooled wire
//!   tensors (`http::codec::parse_predict_body`) and to serialize a
//!   Predict response back to JSON, at several batch sizes;
//! * **codec matrix**: the same decode across every negotiable wire
//!   codec — scalar JSON, the SWAR/SIMD fast path, and binary
//!   `application/x-tensorserve` framing — so the per-codec gap is a
//!   tracked number, not folklore;
//! * **e2e**: requests/sec through the full gateway (HTTP parse →
//!   router → ServerCore → synthetic servable → JSON reply) over
//!   kept-alive loopback connections, against the binary-RPC path on
//!   the same server for comparison.
//!
//! Emits BENCH_http.json for the perf trajectory.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::http::client::HttpClient;
use tensorserve::http::codec;
use tensorserve::http::wire::simd::{parse_predict_fast, simd_level, FastResult};
use tensorserve::inference::ModelSpec;
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{decode_predict_payload, encode_predict_payload, Request};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::runtime::hlo_servable::synthetic_loader;
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::ServerConfig;
use tensorserve::util::bench::{fmt_count, measure, ns_per_iter, Table};
use tensorserve::util::json::Json;
use tensorserve::util::metrics::Histogram;
use tensorserve::util::pool::BufferPool;

const INPUT_DIM: usize = 32;

fn instances_body(rows: usize) -> String {
    let row: Vec<String> = (0..INPUT_DIM).map(|j| format!("{}", j as f64 * 0.125)).collect();
    let row = format!("[{}]", row.join(","));
    format!("{{\"instances\": [{}]}}", vec![row; rows].join(","))
}

fn server_with_synthetic() -> Arc<ModelServer> {
    let server = ModelServer::start(ServerConfig {
        http_addr: Some("127.0.0.1:0".to_string()),
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        ..Default::default()
    })
    .unwrap();
    server
        .avm()
        .basic()
        .load_and_wait(
            ServableId::new("syn", 1),
            synthetic_loader(ArtifactSpec::synthetic_classifier("syn", 1, INPUT_DIM, 4)),
            Duration::from_secs(30),
        )
        .unwrap();
    server
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let warmup = tensorserve::util::bench::bench_duration(Duration::from_millis(200));
    let dur = tensorserve::util::bench::bench_duration(Duration::from_secs(1));

    // ---- codec ns/op -------------------------------------------------
    let mut t = Table::new(
        "H1: JSON ingress codec (row format, pooled decode)",
        &["rows", "decode ns/op", "encode ns/op", "body bytes"],
    );
    let mut codec_json = Vec::new();
    for rows in [1usize, 8, 64] {
        let body = instances_body(rows);
        let bytes = body.as_bytes();
        let (iters, elapsed) = measure(warmup, dur, || {
            let parsed = codec::parse_predict_body(bytes).unwrap();
            // Steady state: the decoded tensor goes back to the pool,
            // exactly as ServerCore::handle does after inference.
            for (_, tensor) in parsed.inputs {
                tensor.recycle_into(&BufferPool::global());
            }
        });
        let decode_ns = ns_per_iter(iters, elapsed);

        // Response encode over a representative 2-output reply.
        let resp = tensorserve::rpc::proto::Response::Predict {
            model_version: 1,
            outputs: vec![
                (
                    "log_probs".into(),
                    tensorserve::runtime::pjrt::OutTensor::F32(Tensor::zeros(vec![rows, 4])),
                ),
                (
                    "class".into(),
                    tensorserve::runtime::pjrt::OutTensor::I32(
                        tensorserve::base::tensor::TensorI32::new(
                            vec![rows],
                            vec![0; rows],
                        )
                        .unwrap(),
                    ),
                ),
            ],
        };
        let (iters, elapsed) = measure(warmup, dur, || {
            let json = codec::predict_response_json(&resp, true).unwrap();
            std::hint::black_box(json.to_string());
        });
        let encode_ns = ns_per_iter(iters, elapsed);

        t.row(vec![
            rows.to_string(),
            format!("{decode_ns:.0}"),
            format!("{encode_ns:.0}"),
            bytes.len().to_string(),
        ]);
        codec_json.push(Json::obj(vec![
            ("rows", Json::num(rows as f64)),
            ("decode_ns_per_op", Json::num(decode_ns)),
            ("encode_ns_per_op", Json::num(encode_ns)),
            ("body_bytes", Json::num(bytes.len() as f64)),
        ]));
    }
    t.print();

    // ---- per-codec decode matrix -------------------------------------
    // The same rows through each negotiable wire codec: the scalar
    // JSON tree parse, the SWAR/SIMD fast path (no Json tree), and the
    // RPC plane's binary tensor framing as served under
    // application/x-tensorserve.
    let level = simd_level().name();
    let title = format!("H1c: per-codec decode ns/op (SIMD level: {level})");
    let mut t = Table::new(
        &title,
        &[
            "rows",
            "scalar json",
            "simd json",
            "binary",
            "json bytes",
            "binary bytes",
        ],
    );
    let mut matrix_json = Vec::new();
    for rows in [1usize, 8, 64] {
        let body = instances_body(rows);
        let bytes = body.as_bytes();
        let tensor = Tensor::matrix(
            (0..rows)
                .map(|_| (0..INPUT_DIM).map(|j| j as f32 * 0.125).collect())
                .collect(),
        )
        .unwrap();
        let mut bin = Vec::new();
        encode_predict_payload(&mut bin, "", &[("x".into(), tensor)]);

        let (iters, elapsed) = measure(warmup, dur, || {
            let parsed = codec::parse_predict_body(bytes).unwrap();
            for (_, tensor) in parsed.inputs {
                tensor.recycle_into(&BufferPool::global());
            }
        });
        let scalar_ns = ns_per_iter(iters, elapsed);

        let (iters, elapsed) = measure(warmup, dur, || match parse_predict_fast(bytes) {
            FastResult::Parsed(parsed) => {
                for (_, tensor) in parsed.inputs {
                    tensor.recycle_into(&BufferPool::global());
                }
            }
            FastResult::Fallback(_) => unreachable!("canonical body must take the fast path"),
        });
        let simd_ns = ns_per_iter(iters, elapsed);

        let (iters, elapsed) = measure(warmup, dur, || {
            let (_, inputs) = decode_predict_payload(&bin).unwrap();
            for (_, tensor) in inputs {
                tensor.recycle_into(&BufferPool::global());
            }
        });
        let binary_ns = ns_per_iter(iters, elapsed);

        t.row(vec![
            rows.to_string(),
            format!("{scalar_ns:.0}"),
            format!("{simd_ns:.0}"),
            format!("{binary_ns:.0}"),
            bytes.len().to_string(),
            bin.len().to_string(),
        ]);
        matrix_json.push(Json::obj(vec![
            ("rows", Json::num(rows as f64)),
            ("scalar_json_ns_per_op", Json::num(scalar_ns)),
            ("simd_json_ns_per_op", Json::num(simd_ns)),
            ("binary_ns_per_op", Json::num(binary_ns)),
            ("json_bytes", Json::num(bytes.len() as f64)),
            ("binary_bytes", Json::num(bin.len() as f64)),
        ]));
    }
    t.print();

    // ---- e2e requests/sec: REST vs binary RPC ------------------------
    let server = server_with_synthetic();
    let http_addr = server.http_addr().unwrap().to_string();
    let rpc_addr = server.addr().to_string();
    let mut t = Table::new(
        "H1b: end-to-end gateway throughput (8-row predict, keep-alive)",
        &["plane", "threads", "req/s", "p50", "p99"],
    );
    let mut e2e_json = Vec::new();
    for threads in [1usize, 4] {
        for plane in ["rest", "rpc"] {
            let latency = Arc::new(Histogram::new());
            let deadline = Instant::now() + Duration::from_secs(2);
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    let http_addr = http_addr.clone();
                    let rpc_addr = rpc_addr.clone();
                    let latency = Arc::clone(&latency);
                    let body = instances_body(8);
                    std::thread::spawn(move || -> u64 {
                        let mut count = 0u64;
                        if plane == "rest" {
                            let mut c = HttpClient::connect(&http_addr).unwrap();
                            while Instant::now() < deadline {
                                let t0 = Instant::now();
                                let (status, _) =
                                    c.post_json("/v1/models/syn:predict", &body).unwrap();
                                latency.record_duration(t0.elapsed());
                                assert_eq!(status, 200);
                                count += 1;
                            }
                        } else {
                            let mut c = RpcClient::connect(&rpc_addr).unwrap();
                            let req = Request::Predict {
                                spec: ModelSpec::latest("syn"),
                                signature: String::new(),
                                inputs: vec![(
                                    "x".into(),
                                    Tensor::zeros(vec![8, INPUT_DIM]),
                                )],
                            };
                            while Instant::now() < deadline {
                                let t0 = Instant::now();
                                c.call_ok(&req).unwrap();
                                latency.record_duration(t0.elapsed());
                                count += 1;
                            }
                        }
                        count
                    })
                })
                .collect();
            let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            let qps = total as f64 / 2.0;
            let (p50, _, p99, _) = latency.percentiles();
            t.row(vec![
                plane.to_string(),
                threads.to_string(),
                fmt_count(qps),
                tensorserve::util::metrics::fmt_nanos(p50),
                tensorserve::util::metrics::fmt_nanos(p99),
            ]);
            e2e_json.push(Json::obj(vec![
                ("plane", Json::str(plane)),
                ("threads", Json::num(threads as f64)),
                ("requests_per_sec", Json::num(qps)),
                ("p50_ns", Json::num(p50 as f64)),
                ("p99_ns", Json::num(p99 as f64)),
            ]));
        }
    }
    t.print();
    server.stop();

    // ---- machine-readable trajectory: BENCH_http.json ----------------
    let json = Json::obj(vec![
        ("bench", Json::str("bench_http")),
        ("input_dim", Json::num(INPUT_DIM as f64)),
        ("simd_level", Json::str(level)),
        ("codec", Json::Arr(codec_json)),
        ("codec_matrix", Json::Arr(matrix_json)),
        ("e2e", Json::Arr(e2e_json)),
    ]);
    let out = "BENCH_http.json";
    tensorserve::util::bench::write_bench_json(out, &json.to_string_pretty());
}
