//! Experiment T9 — §2.1.2: "One-time use of all threads to load the
//! initial set of servable versions, to speed up server start-up."
//!
//! 32 models, each taking ~25ms to load (I/O + deserialize + compile
//! stand-in). Sequential loading (1 load thread, the steady-state
//! configuration) vs the parallel initial-load path with all cores.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tensorserve::base::loader::{FnLoader, Loader, ResourceEstimate};
use tensorserve::base::servable::{ServableBox, ServableId};
use tensorserve::lifecycle::basic_manager::{BasicManager, ManagerOptions};
use tensorserve::util::bench::Table;

/// 32 models x 25ms; 8 x 5ms in bench-smoke mode (compile+run guard).
fn n_models() -> usize {
    if tensorserve::util::bench::smoke() { 8 } else { 32 }
}

fn load_time() -> Duration {
    if tensorserve::util::bench::smoke() {
        Duration::from_millis(5)
    } else {
        Duration::from_millis(25)
    }
}

fn slow_loader() -> Arc<dyn Loader> {
    let load_time = load_time();
    Arc::new(FnLoader::new(ResourceEstimate::default(), "slow", move || {
        std::thread::sleep(load_time);
        Ok(Arc::new(0u8) as ServableBox)
    }))
}

fn items() -> Vec<(ServableId, Arc<dyn Loader>)> {
    (0..n_models())
        .map(|i| (ServableId::new(format!("m{i}"), 1), slow_loader()))
        .collect()
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);

    let n_models = n_models();
    let mut t = Table::new(
        &format!("T9: initial load of {n_models} models x {}ms each", load_time().as_millis()),
        &["strategy", "threads", "startup time", "speedup"],
    );

    // Sequential baseline (steady-state pool size 1).
    let m = BasicManager::new(ManagerOptions { load_threads: 1, ..Default::default() });
    let t0 = Instant::now();
    let results = m.parallel_initial_load(items(), 1);
    let seq = t0.elapsed();
    assert!(results.iter().all(|(_, r)| r.is_ok()));

    t.row(vec![
        "sequential".into(),
        "1".into(),
        format!("{:.0} ms", seq.as_secs_f64() * 1e3),
        "1.0x".into(),
    ]);

    // Parallel initial load with a few widths up to all cores.
    for threads in [4usize, 8, cores] {
        let m = BasicManager::new(ManagerOptions { load_threads: 1, ..Default::default() });
        let t0 = Instant::now();
        let results = m.parallel_initial_load(items(), threads);
        let par = t0.elapsed();
        assert!(results.iter().all(|(_, r)| r.is_ok()));
        assert_eq!(m.ready_names().len(), n_models);
        t.row(vec![
            "parallel (ours)".into(),
            threads.to_string(),
            format!("{:.0} ms", par.as_secs_f64() * 1e3),
            format!("{:.1}x", seq.as_secs_f64() / par.as_secs_f64()),
        ]);
    }
    t.print();
    println!(
        "\nshape check: startup scales ~linearly with threads until n_models/threads\n\
         rounds up ({} x {}ms = {}ms sequential; ~{}ms at {} threads).",
        n_models,
        load_time().as_millis(),
        n_models as u128 * load_time().as_millis(),
        (n_models as f64 / cores as f64).ceil() * load_time().as_millis() as f64,
        cores
    );
}
