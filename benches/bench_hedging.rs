//! Experiment T6 — §3.1: the TFS² Router "uses hedged backup requests
//! to mitigate latency spikes from transient server issues or
//! inter-request or -model interference."
//!
//! Two replicas serve the same model; each request has a 5% chance of
//! hitting a transient 40ms stall (GC pause / noisy neighbor / loading
//! interference). We compare an unhedged client against hedging with
//! several delays. Paper shape: hedging collapses the p95+ tail at the
//! cost of a small duplicate-request rate.

use std::sync::Arc;
use std::time::Duration;
use tensorserve::rpc::client::ClientPool;
use tensorserve::rpc::hedged::HedgedClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::rpc::server::RpcServer;
use tensorserve::util::bench::Table;
use tensorserve::util::metrics::{fmt_nanos, Histogram};
use tensorserve::util::rng::Rng;

const STALL: Duration = Duration::from_millis(40);
const STALL_PROB: f64 = 0.05;

fn stalling_server(seed: u64) -> Arc<RpcServer> {
    let rng = std::sync::Mutex::new(Rng::new(seed));
    RpcServer::start(
        "127.0.0.1:0",
        Arc::new(move |req| {
            if rng.lock().unwrap().chance(STALL_PROB) {
                std::thread::sleep(STALL);
            }
            match req {
                Request::Ping => Response::Pong,
                _ => Response::Error {
                    kind: tensorserve::base::error::ErrorKind::Internal,
                    message: "no".into(),
                },
            }
        }),
    )
    .unwrap()
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let n_requests: usize =
        if tensorserve::util::bench::smoke() { 100 } else { 1500 };
    let a = stalling_server(1);
    let b = stalling_server(2);
    let replicas = vec![a.addr().to_string(), b.addr().to_string()];

    let mut t = Table::new(
        &format!(
            "T6: hedged requests vs {}% transient {}ms stalls ({} requests)",
            (STALL_PROB * 100.0) as u32,
            STALL.as_millis(),
            n_requests
        ),
        &["client", "p50", "p90", "p99", "max", "hedge rate"],
    );

    // --- unhedged baseline: single replica. ---------------------------
    {
        let pool = ClientPool::new();
        let hist = Histogram::new();
        for _ in 0..n_requests {
            let t0 = std::time::Instant::now();
            pool.call(&replicas[0], &Request::Ping).unwrap();
            hist.record_duration(t0.elapsed());
        }
        let (p50, p90, p99, _) = hist.percentiles();
        t.row(vec![
            "unhedged".into(),
            fmt_nanos(p50),
            fmt_nanos(p90),
            fmt_nanos(p99),
            fmt_nanos(hist.max()),
            "-".into(),
        ]);
    }

    // --- hedged with several delays. ----------------------------------
    for delay_ms in [2u64, 5, 20] {
        let hedged = HedgedClient::new(
            Arc::new(ClientPool::new()),
            Duration::from_millis(delay_ms),
        );
        let hist = Histogram::new();
        for _ in 0..n_requests {
            let t0 = std::time::Instant::now();
            hedged.call(&replicas, &Request::Ping).unwrap();
            hist.record_duration(t0.elapsed());
        }
        let (p50, p90, p99, _) = hist.percentiles();
        t.row(vec![
            format!("hedged @{delay_ms}ms"),
            fmt_nanos(p50),
            fmt_nanos(p90),
            fmt_nanos(p99),
            fmt_nanos(hist.max()),
            format!("{:.1}%", hedged.hedge_rate() * 100.0),
        ]);
    }
    t.print();
    println!(
        "\nshape check: unhedged p99 ≈ the 40ms stall; hedged p99 ≈ hedge delay + rtt\n\
         (a stalled primary is overtaken by the backup); hedge rate ≈ stall probability\n\
         plus a little, and max is bounded by double-stall probability (~0.25%)."
    );
}
