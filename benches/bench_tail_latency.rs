//! Experiment T2 — §4: "we have been able to rein in tail latency
//! substantially while other models or versions are loading, compared
//! to our initial naive implementation."
//!
//! Inference latency percentiles while 64MB model versions load and
//! unload concurrently, under two implementations:
//!
//! * **naive** — what one-off serving systems do first (§1): a
//!   mutex-guarded serving map; loads, unloads and the big `free()`
//!   executed *on the request threads* as they notice pending work.
//! * **optimized (ours)** — §2.1.2: RCU map, isolated load pool,
//!   handle drops deferred to a reclaim thread, `malloc_trim` off the
//!   request path.
//!
//! The absolute numbers are testbed-specific; the paper shape is the
//! gap between naive and optimized p99/p99.9 under load churn.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
use tensorserve::base::error::ErrorKind;
use tensorserve::base::loader::{FnLoader, Loader, ResourceEstimate};
use tensorserve::base::servable::{ServableBox, ServableId};
use tensorserve::base::tensor::Tensor;
use tensorserve::batching::scheduler::{QueueOptions, SchedulerOptions, SharedBatchScheduler};
use tensorserve::batching::session::{
    BatchRunner, BatchingSession, PendingRun, SessionOptions,
};
use tensorserve::inference::null::{null_loader, NullServable};
use tensorserve::lifecycle::basic_manager::{BasicManager, VersionRequest};
use tensorserve::runtime::pjrt::OutTensor;
use tensorserve::serving::{AdmissionConfig, AdmissionControl};
use tensorserve::sim::workload::open_loop;
use tensorserve::util::bench::{bench_duration, fmt_count, Table};
use tensorserve::util::json::Json;
use tensorserve::util::mem::WeightBlob;
use tensorserve::util::metrics::{fmt_nanos, Histogram, Registry};

const BLOB_BYTES: usize = 64 << 20;
const CHURN_PERIOD: Duration = Duration::from_millis(150);
/// Open-loop arrival rate: latency is measured from *arrival*, so any
/// stall (a load blocking the serving path) is charged to every
/// request that arrives during it — the honest tail methodology.
const RATE_QPS: f64 = 20_000.0;

fn blob_loader() -> Arc<dyn Loader> {
    Arc::new(FnLoader::new(
        ResourceEstimate::ram(BLOB_BYTES as u64),
        "blob",
        || Ok(Arc::new(WeightBlob::new(BLOB_BYTES)) as ServableBox),
    ))
}

/// Optimized path: BasicManager with its isolated load pool; a churn
/// thread loads+unloads blob versions while inference runs.
fn run_optimized(dur: Duration) -> tensorserve::sim::workload::RunStats {
    let m = BasicManager::with_defaults();
    m.load_and_wait(ServableId::new("served", 1), null_loader(), Duration::from_secs(10))
        .unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let churn = {
        let m = Arc::clone(&m);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut v = 1u64;
            while !stop.load(Ordering::Relaxed) {
                let id = ServableId::new("churner", v);
                let _ = m.load_and_wait(id.clone(), blob_loader(), Duration::from_secs(30));
                std::thread::sleep(CHURN_PERIOD / 2);
                let _ = m.unload_and_wait(id, Duration::from_secs(30));
                std::thread::sleep(CHURN_PERIOD / 2);
                v += 1;
            }
        })
    };
    let m2 = Arc::clone(&m);
    let stats = open_loop(RATE_QPS, dur, 4, 11, move || {
        let h = m2.handle::<NullServable>("served", VersionRequest::Latest)?;
        h.run(1);
        Ok(())
    });
    stop.store(true, Ordering::Relaxed);
    let _ = churn.join();
    stats
}

/// Naive path — §1's "just put the models in a BigTable, and write a
/// simple server": one mutex-guarded map, and version updates performed
/// *while holding the map lock* (load-inside-critical-section), with
/// the old version freed inline. Every request that arrives during a
/// load/unload blocks on the mutex for the whole operation.
fn run_naive(dur: Duration) -> tensorserve::sim::workload::RunStats {
    enum Entry {
        Served(Arc<NullServable>),
        Blob(WeightBlob),
    }
    struct Naive {
        map: Mutex<HashMap<String, Entry>>,
        last_churn: Mutex<Instant>,
        loads: AtomicU64,
    }
    let naive = Arc::new(Naive {
        map: Mutex::new(HashMap::from([(
            "served".to_string(),
            Entry::Served(Arc::new(NullServable::new())),
        )])),
        last_churn: Mutex::new(Instant::now()),
        loads: AtomicU64::new(0),
    });

    let n2 = Arc::clone(&naive);
    open_loop(RATE_QPS, dur, 4, 11, move || {
        // Whichever request thread notices the deadline performs the
        // version swap inline, UNDER the map lock (the naive pattern).
        let due = {
            let mut last = n2.last_churn.lock().unwrap();
            if last.elapsed() >= CHURN_PERIOD / 2 {
                *last = Instant::now();
                true
            } else {
                false
            }
        };
        if due {
            let mut map = n2.map.lock().unwrap();
            if map.contains_key("churner") {
                // Unload + inline free of 64MB, lock held.
                map.remove("churner");
                tensorserve::util::mem::release_to_os();
            } else {
                // Load of 64MB (allocate + fault pages), lock held.
                map.insert("churner".into(), Entry::Blob(WeightBlob::new(BLOB_BYTES)));
                n2.loads.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Mutex-guarded lookup (blocks whenever a load is in progress).
        let servable = {
            let map = n2.map.lock().unwrap();
            match map.get("served").unwrap() {
                Entry::Served(s) => Arc::clone(s),
                Entry::Blob(_) => unreachable!(),
            }
        };
        servable.run(1);
        Ok(())
    })
}

fn main() {
    tensorserve::util::logging::set_level(tensorserve::util::logging::Level::Error);
    let dur = bench_duration(Duration::from_secs(6));

    let optimized = run_optimized(dur);
    let naive = run_naive(dur);

    let mut t = Table::new(
        "T2: inference latency while 64MB versions load/unload concurrently",
        &["impl", "qps", "p50", "p99", "p99.9", "max"],
    );
    for (label, s) in [("naive", &naive), ("optimized (ours)", &optimized)] {
        let (p50, _, p99, p999) = s.latency.percentiles();
        t.row(vec![
            label.into(),
            fmt_count(s.qps()),
            fmt_nanos(p50),
            fmt_nanos(p99),
            fmt_nanos(p999),
            fmt_nanos(s.latency.max()),
        ]);
    }
    t.print();

    let (_, _, n99, n999) = naive.latency.percentiles();
    let (_, _, o99, o999) = optimized.latency.percentiles();
    println!(
        "\nshape check (paper: tail 'reined in substantially'):\n\
         p99   naive/optimized = {:.1}x\n\
         p99.9 naive/optimized = {:.1}x",
        n99 as f64 / o99.max(1) as f64,
        n999 as f64 / o999.max(1) as f64
    );

    // ---- T2b: fast-model tail while a slow co-tenant saturates ------
    //
    // The other tail hazard: not loads, but a slow co-tenant model on
    // the shared batch worker pool. A dedicated lane
    // (`batching.models[].dedicated_threads`) pins the fast model's
    // p99 regardless of slow-lane saturation; the acceptance bar is
    // saturated p99 ≤ 3× uncontended p99.
    let (iso_unc, iso_sat) = lane_isolation_p99();
    let mut t = Table::new(
        "T2b: fast-model p99, dedicated lane, slow co-tenant (50ms/batch) saturating the shared pool",
        &["condition", "fast p99"],
    );
    t.row(vec!["uncontended".into(), fmt_nanos(iso_unc)]);
    t.row(vec!["slow lane saturated".into(), fmt_nanos(iso_sat)]);
    t.print();
    println!(
        "\nshape check: saturated/uncontended = {:.2}x (must stay ≤ 3x).",
        iso_sat as f64 / iso_unc.max(1) as f64
    );

    // ---- T2c: degradation under overload, with and without deadlines
    //
    // Offered load at 2× capacity against a bounded in-flight cap.
    // Without deadlines every admitted request waits out the whole
    // queue; with per-request deadlines + EDF, work that can't make
    // its budget is dropped before execution, so the latency of the
    // answers actually delivered stays near the budget.
    const OVERLOAD_DEADLINE: Duration = Duration::from_millis(5);
    let no_ddl = run_overload(None);
    let with_ddl = run_overload(Some(OVERLOAD_DEADLINE));
    let mut t = Table::new(
        "T2c: overload (16 clients, cap 8, 2ms device): served-latency under shedding",
        &["mode", "offered", "shed", "expired", "served", "served p99", "served max"],
    );
    for (label, s) in [("no deadline", &no_ddl), ("5ms deadline", &with_ddl)] {
        t.row(vec![
            label.into(),
            s.offered.to_string(),
            s.shed.to_string(),
            s.expired.to_string(),
            s.served.to_string(),
            fmt_nanos(s.p99_ns),
            fmt_nanos(s.max_ns),
        ]);
    }
    t.print();
    println!(
        "\nshape check (served p99, no-deadline/deadline): {:.1}x — \
         deadlines trade answered volume for bounded latency.",
        no_ddl.p99_ns as f64 / with_ddl.p99_ns.max(1) as f64
    );

    // ---- machine-readable trajectory: BENCH_tail_latency.json -------
    let (np50, _, _, _) = naive.latency.percentiles();
    let (op50, _, _, _) = optimized.latency.percentiles();
    let json = Json::obj(vec![
        ("bench", Json::str("bench_tail_latency")),
        (
            "churn",
            Json::obj(vec![
                ("naive_p50_ns", Json::num(np50 as f64)),
                ("naive_p99_ns", Json::num(n99 as f64)),
                ("naive_p999_ns", Json::num(n999 as f64)),
                ("optimized_p50_ns", Json::num(op50 as f64)),
                ("optimized_p99_ns", Json::num(o99 as f64)),
                ("optimized_p999_ns", Json::num(o999 as f64)),
                ("p99_improvement", Json::num(n99 as f64 / o99.max(1) as f64)),
            ]),
        ),
        (
            "lane_isolation",
            Json::obj(vec![
                ("fast_p99_uncontended_ns", Json::num(iso_unc as f64)),
                ("fast_p99_slow_lane_saturated_ns", Json::num(iso_sat as f64)),
                (
                    "saturated_over_uncontended",
                    Json::num(iso_sat as f64 / iso_unc.max(1) as f64),
                ),
            ]),
        ),
        (
            "deadline_overload",
            Json::obj(vec![
                ("deadline_ms", Json::num(OVERLOAD_DEADLINE.as_millis() as f64)),
                ("offered", Json::num(with_ddl.offered as f64)),
                ("shed", Json::num(with_ddl.shed as f64)),
                ("expired", Json::num(with_ddl.expired as f64)),
                ("served", Json::num(with_ddl.served as f64)),
                (
                    "shed_rate",
                    Json::num(with_ddl.shed as f64 / with_ddl.offered.max(1) as f64),
                ),
                ("admitted_p99_ns", Json::num(with_ddl.p99_ns as f64)),
                ("no_deadline_p99_ns", Json::num(no_ddl.p99_ns as f64)),
                (
                    "p99_improvement",
                    Json::num(no_ddl.p99_ns as f64 / with_ddl.p99_ns.max(1) as f64),
                ),
            ]),
        ),
    ]);
    let out = "BENCH_tail_latency.json";
    tensorserve::util::bench::write_bench_json(out, &json.to_string_pretty());
}

// NOTE: rust/tests/serving_concurrency.rs asserts the acceptance gate
// (saturated p99 ≤ 3× uncontended) over this same slow/fast scenario —
// keep the two harnesses' parameters in sync when tuning.

/// Device that sleeps per batch — the slow co-tenant.
struct SleepRunner(Duration);

impl BatchRunner for SleepRunner {
    fn run_batch(&self, input: Tensor) -> anyhow::Result<Vec<OutTensor>> {
        std::thread::sleep(self.0);
        Ok(vec![OutTensor::F32(Tensor::new(
            input.shape().to_vec(),
            input.data().to_vec(),
        )?)])
    }
}

fn lane_session(
    sched: &SharedBatchScheduler<PendingRun>,
    name: &str,
    device_time: Duration,
    dedicated_threads: usize,
) -> BatchingSession {
    BatchingSession::new(
        sched,
        name,
        SessionOptions {
            queue: QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::from_micros(100),
                max_enqueued_batches: 1 << 20,
                dedicated_threads,
                ..Default::default()
            },
            allowed_batch_sizes: vec![1],
            ..Default::default()
        },
        Arc::new(SleepRunner(device_time)),
    )
}

/// (uncontended p99, slow-lane-saturated p99) in ns for a fast model
/// on a dedicated lane, 2 shared workers occupied by 50ms batches.
fn lane_isolation_p99() -> (u64, u64) {
    let n = if tensorserve::util::bench::smoke() { 20 } else { 200 };
    let sched = Arc::new(SharedBatchScheduler::new(SchedulerOptions {
        num_batch_threads: 2,
        name: "iso".into(),
    }));
    let slow = Arc::new(lane_session(&sched, "slow", Duration::from_millis(50), 0));
    let fast = lane_session(&sched, "fast", Duration::ZERO, 1);

    let measure = |n: usize| {
        let hist = Histogram::new();
        for i in 0..n {
            let t0 = Instant::now();
            fast.run(Tensor::matrix(vec![vec![i as f32]]).unwrap()).unwrap();
            hist.record_duration(t0.elapsed());
            std::thread::sleep(Duration::from_micros(500));
        }
        hist.quantile(0.99)
    };

    let uncontended = measure(n);

    let stop = Arc::new(AtomicBool::new(false));
    let pumps: Vec<_> = (0..2)
        .map(|_| {
            let slow = Arc::clone(&slow);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let _ = slow.run(Tensor::matrix(vec![vec![1.0]]).unwrap());
                }
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(60));
    let saturated = measure(n);
    stop.store(true, Ordering::Relaxed);
    for p in pumps {
        p.join().unwrap();
    }
    (uncontended, saturated)
}

// ------------------------- T2c: deadline-aware overload degradation

struct OverloadStats {
    offered: u64,
    shed: u64,
    expired: u64,
    served: u64,
    /// p99 (ns) of the requests that were actually answered.
    p99_ns: u64,
    max_ns: u64,
}

/// 16 closed-loop clients against a 2ms-per-batch device with 2
/// workers and a global in-flight cap of 8 — offered load well past
/// capacity. Requests either get shed at admission, expire in queue
/// (when `deadline` is set), or complete; only completions count
/// toward the latency histogram.
fn run_overload(deadline: Option<Duration>) -> OverloadStats {
    const THREADS: usize = 16;
    let per_thread: usize = if tensorserve::util::bench::smoke() { 40 } else { 150 };
    let sched = Arc::new(SharedBatchScheduler::new(SchedulerOptions {
        num_batch_threads: 2,
        name: "overload".into(),
    }));
    let session = Arc::new(lane_session(&sched, "m", Duration::from_millis(2), 0));
    let metrics = Registry::new();
    let admission = AdmissionControl::new(
        AdmissionConfig {
            max_inflight: 8,
            max_inflight_per_model: 0,
            retry_after_ms: 1000,
        },
        &metrics,
    );

    let workers: Vec<_> = (0..THREADS)
        .map(|_| {
            let session = Arc::clone(&session);
            let admission = Arc::clone(&admission);
            std::thread::spawn(move || {
                let mut latencies = Vec::with_capacity(per_thread);
                let (mut shed, mut expired) = (0u64, 0u64);
                for i in 0..per_thread {
                    let _permit = match admission.admit("m") {
                        Ok(p) => p,
                        Err(_) => {
                            shed += 1;
                            continue;
                        }
                    };
                    let t0 = Instant::now();
                    let d = deadline.map(|d| t0 + d);
                    match session.run_with_deadline(
                        Tensor::matrix(vec![vec![i as f32]]).unwrap(),
                        d,
                    ) {
                        Ok(_) => latencies.push(t0.elapsed().as_nanos() as u64),
                        Err(e) if ErrorKind::of(&e) == ErrorKind::DeadlineExceeded => {
                            expired += 1;
                        }
                        Err(e) => panic!("unexpected overload error: {e}"),
                    }
                }
                (latencies, shed, expired)
            })
        })
        .collect();

    let hist = Histogram::new();
    let (mut shed, mut expired, mut served) = (0u64, 0u64, 0u64);
    for w in workers {
        let (latencies, s, x) = w.join().unwrap();
        shed += s;
        expired += x;
        served += latencies.len() as u64;
        for ns in latencies {
            hist.record_duration(Duration::from_nanos(ns));
        }
    }
    OverloadStats {
        offered: (THREADS * per_thread) as u64,
        shed,
        expired,
        served,
        p99_ns: hist.quantile(0.99),
        max_ns: hist.max(),
    }
}
